"""Hypothesis-free smoke parity: Pallas kernels vs the numpy oracle.

The full property suites (test_linear_kernel / test_affine_kernel) need
the ``hypothesis`` package, which minimal CI runners may not ship. This
module needs only numpy + jax and pins fixed seeds, so any runner that
can execute Pallas at all gets end-to-end kernel coverage.
"""

import pytest

pytest.importorskip("jax")

import jax.numpy as jnp
import numpy as np

from compile.kernels import ref
from compile.kernels.affine_wf import affine_wf
from compile.kernels.linear_wf import linear_wf
from compile.params import BAND, ETH, window_len

NS = (8, 24)
SEEDS = (0, 1, 2)


def _pair(rng, n, planted):
    read = rng.integers(0, 4, n).astype(np.int32)
    win = rng.integers(0, 4, window_len(n)).astype(np.int32)
    if planted:
        shift = int(rng.integers(0, 2 * ETH + 1))
        take = min(n, window_len(n) - shift)
        win[shift : shift + take] = read[:take]
        # shift + p < n + 2*ETH == window_len(n) always, so this is in range
        for _ in range(int(rng.integers(0, 3))):
            p = int(rng.integers(0, n))
            win[shift + p] ^= 1
    return read, win


def _batch(rng, b, n, planted):
    pairs = [_pair(rng, n, planted) for _ in range(b)]
    reads = jnp.asarray(np.stack([p[0] for p in pairs]))
    wins = jnp.asarray(np.stack([p[1] for p in pairs]))
    return pairs, reads, wins


def test_linear_kernel_matches_oracle_fixed_seeds():
    for seed in SEEDS:
        for n in NS:
            for planted in (False, True):
                rng = np.random.default_rng(seed)
                pairs, reads, wins = _batch(rng, 4, n, planted)
                got = np.asarray(linear_wf(reads, wins))
                for i, (read, win) in enumerate(pairs):
                    want = ref.linear_wf_band(read, win)
                    np.testing.assert_array_equal(
                        got[i], want, err_msg=f"seed={seed} n={n} planted={planted} i={i}"
                    )


def test_affine_kernel_matches_oracle_fixed_seeds():
    for seed in SEEDS:
        for n in NS:
            rng = np.random.default_rng(seed + 100)
            pairs, reads, wins = _batch(rng, 2, n, True)
            band, dirs = affine_wf(reads, wins)
            band, dirs = np.asarray(band), np.asarray(dirs)
            assert band.shape == (2, BAND)
            assert dirs.shape == (2, n, BAND)
            for i, (read, win) in enumerate(pairs):
                want_band, want_dirs = ref.affine_wf_band(read, win)
                np.testing.assert_array_equal(band[i], want_band, err_msg=f"band i={i} n={n}")
                np.testing.assert_array_equal(dirs[i], want_dirs, err_msg=f"dirs i={i} n={n}")


def test_exact_plant_scores_zero():
    rng = np.random.default_rng(7)
    read = rng.integers(0, 4, 16).astype(np.int32)
    win = rng.integers(0, 4, window_len(16)).astype(np.int32)
    win[ETH : ETH + 16] = read
    band = np.asarray(linear_wf(jnp.asarray(read[None, :]), jnp.asarray(win[None, :])))
    assert band[0, ETH] == 0
