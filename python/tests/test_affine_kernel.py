"""Pallas affine WF kernel vs the serial numpy oracle + traceback laws.

The affine kernel must reproduce the oracle bit-for-bit (band values AND
packed direction codes — the directions feed the Rust traceback, so the
tie-breaking must be deterministic and identical). Traceback itself is
validated through two invariants:

  1. cost identity:   script_cost(traceback(dirs)) == band distance
  2. structural:      applying the script to the window re-derives the
                      read at every '=' position
"""

import pytest

pytest.importorskip("jax")
pytest.importorskip("hypothesis")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.affine_wf import affine_wf
from compile.model import best_of_band
from compile.params import BAND, ETH, SAT_AFFINE, W_EX, W_OP, window_len
from tests.test_linear_kernel import batch, planted_pair, rand_pair

NS = (8, 16, 24, 40)


def kernel_single(read, win):
    band, dirs = affine_wf(*batch([(read, win)]), block=1)
    return np.asarray(band)[0], np.asarray(dirs)[0]


@settings(deadline=None, max_examples=40)
@given(
    n=st.sampled_from(NS),
    b=st.sampled_from((1, 2, 4)),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_matches_oracle_random(n, b, seed):
    rng = np.random.default_rng(seed)
    pairs = [rand_pair(rng, n) for _ in range(b)]
    reads, wins = batch(pairs)
    band, dirs = affine_wf(reads, wins, block=b)
    band, dirs = np.asarray(band), np.asarray(dirs)
    for i, (read, win) in enumerate(pairs):
        eband, edirs = ref.affine_wf_band(read, win)
        np.testing.assert_array_equal(band[i], eband)
        np.testing.assert_array_equal(dirs[i], edirs)


@settings(deadline=None, max_examples=50)
@given(
    n=st.sampled_from(NS),
    n_sub=st.integers(0, 3),
    n_del=st.integers(0, 2),
    n_ins=st.integers(0, 2),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_matches_oracle_planted(n, n_sub, n_del, n_ins, seed):
    rng = np.random.default_rng(seed)
    read, win = planted_pair(rng, n, n_sub, n_del, n_ins)
    band, dirs = kernel_single(read, win)
    eband, edirs = ref.affine_wf_band(read, win)
    np.testing.assert_array_equal(band, eband)
    np.testing.assert_array_equal(dirs, edirs)


@settings(deadline=None, max_examples=60)
@given(
    n=st.sampled_from(NS),
    n_sub=st.integers(0, 3),
    n_del=st.integers(0, 2),
    n_ins=st.integers(0, 2),
    seed=st.integers(0, 2**32 - 1),
)
def test_traceback_cost_identity(n, n_sub, n_del, n_ins, seed):
    rng = np.random.default_rng(seed)
    read, win = planted_pair(rng, n, n_sub, n_del, n_ins)
    band, dirs = kernel_single(read, win)
    j = int(
        np.argmin(band * 1024 + np.abs(np.arange(BAND) - ETH) * 16 + np.arange(BAND))
    )
    if band[j] >= SAT_AFFINE:
        return  # saturated: traceback undefined by design
    ops, j_end = ref.traceback(dirs, j)
    assert ref.script_cost(ops, j_end) == band[j]
    applied = ref.apply_script(ops, j_end, win, n)
    mask = applied >= 0
    np.testing.assert_array_equal(applied[mask], read[mask])


@settings(deadline=None, max_examples=30)
@given(n=st.sampled_from(NS), seed=st.integers(0, 2**32 - 1))
def test_affine_upper_bounds_sub_only(n, seed):
    """With substitutions only, the affine distance equals the number of
    planted substitutions + anchoring (gaps can only cost more)."""
    rng = np.random.default_rng(seed)
    n_sub = int(rng.integers(0, 4))
    read, win = planted_pair(rng, n, n_sub, 0, 0, shift=ETH)
    band, _ = kernel_single(read, win)
    assert band[ETH] <= n_sub


@settings(deadline=None, max_examples=30)
@given(n=st.sampled_from((16, 24, 40)), gap=st.integers(1, 3), seed=st.integers(0, 2**32 - 1))
def test_gap_run_costs_affine_penalty(n, gap, seed):
    """A single planted gap of length L costs exactly w_op + L*w_ex
    (plus nothing else) when the rest matches exactly."""
    rng = np.random.default_rng(seed)
    read = rng.integers(0, 4, n).astype(np.int32)
    seq = list(read)
    p = n // 2
    for _ in range(gap):  # delete a run from the window copy => read insertion
        del seq[p]
    m = window_len(n)
    win = rng.integers(0, 4, m).astype(np.int32)
    win[ETH : ETH + len(seq)] = seq
    band, dirs = kernel_single(read, win)
    best = band.min()
    assert best <= W_OP + gap * W_EX
    if best == W_OP + gap * W_EX:
        j = int(np.argmin(band * 1024 + np.abs(np.arange(BAND) - ETH) * 16 + np.arange(BAND)))
        ops, j_end = ref.traceback(dirs, j)
        # the optimal script either uses the planted gap run or found an
        # equal-cost substitution path (possible for short reads where
        # #subs == w_op + gap*w_ex); both must satisfy the cost identity
        has_gap_run = f"{'I' * gap}" in ops or f"{'D' * gap}" in ops
        all_subs = ops.count("X") == best and "I" not in ops and "D" not in ops
        assert has_gap_run or all_subs, (ops, best)
        assert ref.script_cost(ops, j_end) == best


def test_match_row_is_anchor_costs():
    """Exact placement at the anchor: distance 0 at center, |j-eth| shape
    preserved at the edges of the final band."""
    rng = np.random.default_rng(11)
    read, win = planted_pair(rng, 40, 0, 0, 0, shift=ETH)
    band, dirs = kernel_single(read, win)
    assert band[ETH] == 0
    ops, j_end = ref.traceback(dirs, ETH)
    assert ops == "=" * 40 and j_end == ETH


def test_best_of_band_tie_breaks():
    band = jnp.asarray(
        [
            [5, 3, 3, 9, 9, 9, 9, 9, 9, 9, 9, 9, 9],  # tie at j=1,2 -> closer to eth wins (j=2)
            [9, 9, 9, 9, 9, 2, 9, 2, 9, 9, 9, 9, 9],  # tie |j-eth|=1 -> smaller j (5)
            [9, 9, 9, 9, 9, 9, 0, 9, 9, 9, 9, 9, 9],  # center
        ],
        dtype=jnp.int32,
    )
    best, bj = best_of_band(band)
    np.testing.assert_array_equal(np.asarray(best), [3, 2, 0])
    np.testing.assert_array_equal(np.asarray(bj), [2, 5, 6])


def test_dirs_fit_in_four_bits():
    rng = np.random.default_rng(13)
    read, win = planted_pair(rng, 40, 2, 1, 1)
    _, dirs = kernel_single(read, win)
    assert dirs.min() >= 0 and dirs.max() < 16
