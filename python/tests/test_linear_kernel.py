"""Pallas linear WF kernel vs the serial numpy oracle.

Hypothesis sweeps shapes, random strings, and planted near-matches; the
kernel must agree with ref.linear_wf_band cell-for-cell, and the rolling
oracle must agree with the structurally independent full-matrix DP.
"""

import pytest

pytest.importorskip("jax")
pytest.importorskip("hypothesis")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.linear_wf import linear_wf, prefix_min_ramp
from compile.params import BAND, BIG, ETH, SAT_LINEAR, window_len

# Small palette of shapes so jit caches stay warm across hypothesis runs.
NS = (8, 16, 24, 40)
BS = (1, 2, 4)


def rand_pair(rng, n):
    read = rng.integers(0, 4, n).astype(np.int32)
    win = rng.integers(0, 4, window_len(n)).astype(np.int32)
    return read, win


def planted_pair(rng, n, n_sub, n_del, n_ins, shift=None):
    """Window containing the read at offset ``shift`` with planted edits."""
    shift = int(rng.integers(0, 2 * ETH + 1)) if shift is None else shift
    read = rng.integers(0, 4, n).astype(np.int32)
    seq = list(read)
    for _ in range(n_del):  # delete read bases from the window copy
        del seq[int(rng.integers(0, len(seq)))]
    for _ in range(n_ins):  # insert extra bases into the window copy
        seq.insert(int(rng.integers(0, len(seq) + 1)), int(rng.integers(0, 4)))
    for _ in range(n_sub):
        p = int(rng.integers(0, len(seq)))
        seq[p] = (seq[p] + 1 + int(rng.integers(0, 3))) % 4
    m = window_len(n)
    win = rng.integers(0, 4, m).astype(np.int32)
    take = min(len(seq), m - shift)
    win[shift : shift + take] = seq[:take]
    return read, win


def batch(pairs):
    reads = np.stack([p[0] for p in pairs])
    wins = np.stack([p[1] for p in pairs])
    return jnp.asarray(reads), jnp.asarray(wins)


@settings(deadline=None, max_examples=40)
@given(
    n=st.sampled_from(NS),
    b=st.sampled_from(BS),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_matches_oracle_random(n, b, seed):
    rng = np.random.default_rng(seed)
    pairs = [rand_pair(rng, n) for _ in range(b)]
    reads, wins = batch(pairs)
    out = np.asarray(linear_wf(reads, wins, block=b))
    for i, (read, win) in enumerate(pairs):
        expect = ref.linear_wf_band(read, win)
        np.testing.assert_array_equal(out[i], expect)


@settings(deadline=None, max_examples=40)
@given(
    n=st.sampled_from(NS),
    n_sub=st.integers(0, 4),
    n_del=st.integers(0, 2),
    n_ins=st.integers(0, 2),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_matches_oracle_planted(n, n_sub, n_del, n_ins, seed):
    rng = np.random.default_rng(seed)
    read, win = planted_pair(rng, n, n_sub, n_del, n_ins)
    out = np.asarray(linear_wf(*batch([(read, win)]), block=1))[0]
    np.testing.assert_array_equal(out, ref.linear_wf_band(read, win))
    # A planted placement with e total edits and shift s costs at most
    # e + |s - eth| + boundary effects; with few edits it must pass eth.
    if n_sub + n_del + n_ins <= 2:
        assert out.min() <= n_sub + 2 * (n_del + n_ins) + 2 * ETH


@settings(deadline=None, max_examples=25)
@given(n=st.sampled_from((8, 16, 24)), seed=st.integers(0, 2**32 - 1))
def test_rolling_oracle_matches_full_matrix(n, seed):
    rng = np.random.default_rng(seed)
    for maker in (lambda: rand_pair(rng, n), lambda: planted_pair(rng, n, 1, 1, 0)):
        read, win = maker()
        np.testing.assert_array_equal(
            ref.linear_wf_band(read, win), ref.linear_wf_full(read, win)
        )


@settings(deadline=None, max_examples=30)
@given(n=st.sampled_from(NS), seed=st.integers(0, 2**32 - 1))
def test_saturation_is_lossless_below_threshold(n, seed):
    """3-bit clamping never changes any band cell that ends below eth+1
    (DP values are non-decreasing along any path)."""
    rng = np.random.default_rng(seed)
    read, win = planted_pair(rng, n, int(rng.integers(0, 3)), 0, 0)
    clamped = ref.linear_wf_band(read, win, clamp=True)
    free = ref.linear_wf_band(read, win, clamp=False)
    for j in range(BAND):
        if free[j] <= ETH:
            assert clamped[j] == free[j]
        else:
            assert clamped[j] == SAT_LINEAR


def test_prefix_min_ramp_exact():
    rng = np.random.default_rng(7)
    x = rng.integers(0, 40, (5, BAND)).astype(np.int32)
    got = np.asarray(prefix_min_ramp(jnp.asarray(x)))
    want = np.empty_like(x)
    for b in range(x.shape[0]):
        for j in range(BAND):
            want[b, j] = min(x[b, k] + (j - k) for k in range(j + 1))
    np.testing.assert_array_equal(got, want)


def test_exact_match_is_zero():
    rng = np.random.default_rng(3)
    read, win = planted_pair(rng, 40, 0, 0, 0, shift=ETH)
    out = np.asarray(linear_wf(*batch([(read, win)]), block=1))[0]
    assert out[ETH] == 0
    assert out.min() == 0


def test_shifted_match_costs_shift():
    rng = np.random.default_rng(4)
    for shift in range(2 * ETH + 1):
        read, win = planted_pair(rng, 40, 0, 0, 0, shift=shift)
        out = np.asarray(linear_wf(*batch([(read, win)]), block=1))[0]
        # anchoring charges |shift - eth|; an exact placement at offset
        # `shift` ends on band diagonal j = shift.
        assert out[shift] <= abs(shift - ETH)


def test_batch_blocks_are_independent():
    """Grid/blocking must not mix instances: permuting the batch permutes
    the outputs."""
    rng = np.random.default_rng(5)
    pairs = [rand_pair(rng, 24) for _ in range(4)]
    reads, wins = batch(pairs)
    out = np.asarray(linear_wf(reads, wins, block=2))
    perm = np.array([2, 0, 3, 1])
    out_p = np.asarray(linear_wf(reads[perm], wins[perm], block=2))
    np.testing.assert_array_equal(out[perm], out_p)


def test_rejects_bad_window_length():
    with pytest.raises(AssertionError):
        linear_wf(jnp.zeros((1, 20), jnp.int32), jnp.zeros((1, 20), jnp.int32))
