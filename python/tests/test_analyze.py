"""End-to-end tests for the dart-analyze static-analysis pass.

Each directory under ``tools/analyze/fixtures/`` is a miniature
repository with either planted violations or a clean counterexample;
``manifest.json`` records the expected ``file:line:check`` triples.
The analyzer is exercised the way CI runs it — as a subprocess with no
Rust toolchain involved — so these tests also pin the exit-code and
output contract (`path:line: [check] message` on stdout, summary on
stderr).
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]
FIXTURES = REPO / "tools" / "analyze" / "fixtures"
MANIFEST = json.loads((FIXTURES / "manifest.json").read_text())


def run_analyze(*args):
    return subprocess.run(
        [sys.executable, "-m", "tools.analyze", *args],
        cwd=REPO,
        capture_output=True,
        text=True,
    )


@pytest.mark.parametrize("case", MANIFEST["cases"], ids=[c["dir"] for c in MANIFEST["cases"]])
def test_fixture(case):
    root = FIXTURES / case["dir"]
    assert root.is_dir(), f"missing fixture directory {root}"
    p = run_analyze("--root", str(root))
    expected = case["findings"]
    if not expected:
        assert p.returncode == 0, f"expected clean, got:\n{p.stdout}{p.stderr}"
        assert "dart-analyze: clean" in p.stderr
        return
    assert p.returncode == 1, f"expected findings, got:\n{p.stdout}{p.stderr}"
    out_lines = [ln for ln in p.stdout.splitlines() if ln.strip()]
    assert len(out_lines) == len(expected), f"finding count mismatch:\n{p.stdout}"
    for f in expected:
        prefix = "{file}:{line}: [{check}]".format(**f)
        assert any(ln.startswith(prefix) for ln in out_lines), f"no `{prefix}` in:\n{p.stdout}"


def test_manifest_covers_every_fixture_dir():
    listed = {c["dir"] for c in MANIFEST["cases"]}
    present = {d.name for d in FIXTURES.iterdir() if d.is_dir()}
    assert listed == present, f"manifest/fixture drift: {listed ^ present}"


def test_check_filter_runs_only_the_named_check():
    p = run_analyze("--root", str(FIXTURES / "msrv_bad"), "--check", "line-length")
    assert p.returncode == 0, p.stdout + p.stderr
    p = run_analyze("--root", str(FIXTURES / "msrv_bad"), "--check", "msrv")
    assert p.returncode == 1, p.stdout + p.stderr


def test_list_checks_names_them_all():
    p = run_analyze("--list-checks")
    assert p.returncode == 0
    names = p.stdout.split()
    assert len(names) == 8, names
    for expected in ("struct-exhaustive", "determinism", "unsafe", "cli-docs"):
        assert expected in names


def test_full_tree_is_clean():
    p = run_analyze()
    assert p.returncode == 0, f"the real tree must stay clean:\n{p.stdout}{p.stderr}"
