"""End-to-end tests for the dart-analyze static-analysis pass.

Each directory under ``tools/analyze/fixtures/`` is a miniature
repository with either planted violations or a clean counterexample;
``manifest.json`` records the expected ``file:line:check`` triples.
The analyzer is exercised the way CI runs it — as a subprocess with no
Rust toolchain involved — so these tests also pin the exit-code and
output contract (`path:line: [check] message` on stdout, summary on
stderr).
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]
FIXTURES = REPO / "tools" / "analyze" / "fixtures"
MANIFEST = json.loads((FIXTURES / "manifest.json").read_text())


def run_analyze(*args):
    return subprocess.run(
        [sys.executable, "-m", "tools.analyze", *args],
        cwd=REPO,
        capture_output=True,
        text=True,
    )


@pytest.mark.parametrize("case", MANIFEST["cases"], ids=[c["dir"] for c in MANIFEST["cases"]])
def test_fixture(case):
    root = FIXTURES / case["dir"]
    assert root.is_dir(), f"missing fixture directory {root}"
    p = run_analyze("--root", str(root))
    expected = case["findings"]
    if not expected:
        assert p.returncode == 0, f"expected clean, got:\n{p.stdout}{p.stderr}"
        assert "dart-analyze: clean" in p.stderr
        return
    assert p.returncode == 1, f"expected findings, got:\n{p.stdout}{p.stderr}"
    out_lines = [ln for ln in p.stdout.splitlines() if ln.strip()]
    assert len(out_lines) == len(expected), f"finding count mismatch:\n{p.stdout}"
    for f in expected:
        prefix = "{file}:{line}: [{check}]".format(**f)
        assert any(ln.startswith(prefix) for ln in out_lines), f"no `{prefix}` in:\n{p.stdout}"


def test_manifest_covers_every_fixture_dir():
    listed = {c["dir"] for c in MANIFEST["cases"]}
    present = {d.name for d in FIXTURES.iterdir() if d.is_dir()}
    assert listed == present, f"manifest/fixture drift: {listed ^ present}"


def test_check_filter_runs_only_the_named_check():
    p = run_analyze("--root", str(FIXTURES / "msrv_bad"), "--check", "line-length")
    assert p.returncode == 0, p.stdout + p.stderr
    p = run_analyze("--root", str(FIXTURES / "msrv_bad"), "--check", "msrv")
    assert p.returncode == 1, p.stdout + p.stderr


def test_list_checks_names_them_all():
    p = run_analyze("--list-checks")
    assert p.returncode == 0
    names = p.stdout.split()
    assert len(names) == 10, names
    for expected in (
        "struct-exhaustive",
        "determinism",
        "flush-ack",
        "enum-wildcard",
        "unsafe",
        "cli-docs",
    ):
        assert expected in names


def test_full_tree_is_clean():
    p = run_analyze()
    assert p.returncode == 0, f"the real tree must stay clean:\n{p.stdout}{p.stderr}"


# ---------------------------------------------------------------------
# lexer span round-trip: token/comment byte offsets must reconstruct
# the exact source slice over the entire real Rust tree.

sys.path.insert(0, str(REPO))


def _rust_sources():
    for scan in ("rust/src", "rust/tests", "rust/benches", "examples"):
        base = REPO / scan
        if base.is_dir():
            yield from sorted(base.rglob("*.rs"))


def test_lexer_spans_round_trip_over_the_whole_tree():
    from tools.analyze.lexer import lex

    files = list(_rust_sources())
    assert files, "no Rust sources found"
    for path in files:
        src = path.read_text(encoding="utf-8", errors="replace")
        toks, comments = lex(src)
        prev_end = 0
        for t in toks:
            assert t.start >= prev_end >= 0, f"{path}: overlapping span at {t}"
            assert src[t.start : t.end] == t.text, f"{path}: span mismatch at {t}"
            prev_end = t.end
        for c in comments:
            assert src[c.start : c.end] == c.text, f"{path}: comment span mismatch"


def test_lexer_spans_cover_tricky_literals():
    from tools.analyze.lexer import lex

    src = 'let a = r#"x"#; let b = \'q\'; let c: &\'static str = "s"; // t\n'
    toks, comments = lex(src)
    for t in toks:
        assert src[t.start : t.end] == t.text, t
    (c,) = comments
    assert src[c.start : c.end] == "// t"


# ---------------------------------------------------------------------
# items + call graph unit behavior (in-process, no subprocess)


def test_items_recovers_fns_enums_and_uses():
    from tools.analyze.items import parse_file
    from tools.analyze.model import SourceFile

    src = """
use std::collections::HashMap as Map;
mod inner {
    fn helper() {}
}
enum PoolMsg {
    Items { n: u32 },
    Flush { session: u64, ack: Sender },
}
impl Worker {
    fn run(&self) {
        fn nested() {}
        self.step();
    }
}
"""
    fi = parse_file(SourceFile.parse("rust/src/coordinator/pool.rs", src))
    by_name = {f.name: f for f in fi.fns}
    assert by_name["helper"].qual == ("coordinator", "pool", "inner")
    assert by_name["run"].self_type == "Worker"
    assert by_name["nested"].self_type is None
    (enum,) = fi.enums
    assert [v.name for v in enum.variants] == ["Items", "Flush"]
    assert enum.variants[1].fields == ("session", "ack")
    assert fi.uses["Map"] == ("std", "collections", "HashMap")


def test_callgraph_reaches_transitively_and_stops_at_unlinked_fns():
    from tools.analyze.callgraph import CallGraph
    from tools.analyze.model import SourceFile

    files = {
        "rust/src/a.rs": SourceFile.parse(
            "rust/src/a.rs", "fn sink() { mid(); }\nfn mid() { crate::b::leaf(); }\n"
        ),
        "rust/src/b.rs": SourceFile.parse(
            "rust/src/b.rs", "fn leaf() {}\nfn island() { leaf(); }\n"
        ),
    }
    g = CallGraph(files)
    (sink,) = g.find("rust/src/a.rs", "sink")
    parents = g.reachable([sink.key])
    names = {g.fns[k].name for k in parents}
    assert names == {"sink", "mid", "leaf"}
    assert "island" not in names
    (leaf,) = g.find("rust/src/b.rs", "leaf")
    assert g.chain(parents, leaf.key) == ["sink", "mid", "leaf"]


def test_transitive_hazard_is_invisible_to_a_per_file_scan():
    # The acceptance fixture: the sink's own file contains no hazard
    # identifier at all, so any per-file grep of cli.rs comes up empty;
    # only call-graph reachability ties util.rs's HashSet to the sink.
    root = FIXTURES / "taint_transitive_bad"
    caller = (root / "rust/src/cli.rs").read_text()
    assert "HashSet" not in caller and "HashMap" not in caller
    p = run_analyze("--root", str(root), "--check", "determinism")
    assert p.returncode == 1
    assert "rust/src/util.rs:7" in p.stdout
    assert "cmd_map -> dedup_order" in p.stdout


# ---------------------------------------------------------------------
# output formats, --changed scoping, bench budget, fixture gate


def test_sarif_output_is_valid_and_locates_findings():
    p = run_analyze("--root", str(FIXTURES / "enum_wildcard_bad"), "--format", "sarif")
    assert p.returncode == 1
    doc = json.loads(p.stdout)
    assert doc["version"] == "2.1.0"
    (run_,) = doc["runs"]
    assert run_["tool"]["driver"]["name"] == "dart-analyze"
    rules = {r["id"] for r in run_["tool"]["driver"]["rules"]}
    assert {"determinism", "flush-ack", "enum-wildcard", "annotation"} <= rules
    locs = {
        (
            r["ruleId"],
            r["locations"][0]["physicalLocation"]["artifactLocation"]["uri"],
            r["locations"][0]["physicalLocation"]["region"]["startLine"],
        )
        for r in run_["results"]
    }
    assert ("enum-wildcard", "rust/src/case.rs", 15) in locs


def test_github_output_emits_error_annotations():
    p = run_analyze("--root", str(FIXTURES / "determinism_bad"), "--format", "github")
    assert p.returncode == 1
    (line,) = [ln for ln in p.stdout.splitlines() if ln.startswith("::error")]
    assert line.startswith("::error file=rust/src/cli.rs,line=12::[determinism]")


def test_changed_scoping_filters_findings_but_not_analysis(tmp_path):
    # the hazard lives in util.rs; a change-set naming only cli.rs must
    # report nothing, while one naming util.rs reports the finding —
    # in both cases resolution ran over the whole tree.
    listing = tmp_path / "changed.txt"
    listing.write_text("rust/src/cli.rs\n")
    p = run_analyze(
        "--root", str(FIXTURES / "taint_transitive_bad"), "--changed-from", str(listing)
    )
    assert p.returncode == 0, p.stdout + p.stderr
    assert "[changed: 1 path(s)]" in p.stderr
    listing.write_text("rust/src/util.rs\n")
    p = run_analyze(
        "--root", str(FIXTURES / "taint_transitive_bad"), "--changed-from", str(listing)
    )
    assert p.returncode == 1
    assert "rust/src/util.rs:7" in p.stdout


def test_bench_writes_budget_json(tmp_path):
    out = tmp_path / "BENCH_analyze.json"
    p = run_analyze("--bench", str(out), "--budget-s", "60")
    assert p.returncode == 0, p.stdout + p.stderr
    doc = json.loads(out.read_text())
    assert doc["tool"] == "dart-analyze"
    assert doc["within_budget"] is True
    assert doc["wall_s"] < 60
    assert doc["files"] > 0


def test_bench_budget_overrun_fails(tmp_path):
    out = tmp_path / "bench.json"
    p = run_analyze("--bench", str(out), "--budget-s", "0")
    assert p.returncode == 2
    assert json.loads(out.read_text())["within_budget"] is False


def test_verify_fixtures_gate_passes():
    p = run_analyze("--verify-fixtures")
    assert p.returncode == 0, p.stdout + p.stderr
    assert "drift-free" in p.stderr
