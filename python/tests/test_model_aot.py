"""L2 graph + AOT lowering tests: shapes, determinism, HLO text validity."""

import json
import os

import pytest

pytest.importorskip("jax")
pytest.importorskip("hypothesis")  # helpers come from test_linear_kernel

import jax
import jax.numpy as jnp
import numpy as np

from compile import params
from compile.aot import lower_variant, to_hlo_text
from compile.model import affine_align, linear_filter
from tests.test_linear_kernel import batch, planted_pair


def _mk(rng, b, n=24):
    return batch([planted_pair(rng, n, 1, 0, 0) for _ in range(b)])


def test_linear_filter_shapes():
    rng = np.random.default_rng(0)
    reads, wins = _mk(rng, 4)
    band, best, bj = linear_filter(reads, wins)
    assert band.shape == (4, params.BAND) and band.dtype == jnp.int32
    assert best.shape == (4,) and bj.shape == (4,)
    b, j = np.asarray(best), np.asarray(bj)
    nb = np.asarray(band)
    np.testing.assert_array_equal(b, nb.min(axis=1))
    assert all(nb[i, j[i]] == b[i] for i in range(4))


def test_affine_align_shapes():
    rng = np.random.default_rng(1)
    reads, wins = _mk(rng, 2)
    band, best, bj, dirs = affine_align(reads, wins)
    assert band.shape == (2, params.BAND)
    assert dirs.shape == (2, 24, params.BAND) and dirs.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(best), np.asarray(band).min(axis=1))


def test_graphs_are_deterministic():
    rng = np.random.default_rng(2)
    reads, wins = _mk(rng, 4)
    a = [np.asarray(x) for x in linear_filter(reads, wins)]
    b = [np.asarray(x) for x in linear_filter(reads, wins)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_lowering_produces_parseable_hlo_text():
    text = to_hlo_text(lower_variant(linear_filter, 4, 24))
    assert "HloModule" in text
    assert "ENTRY" in text
    # int32 tensors of the declared shapes appear in the entry signature
    assert "s32[4,24]" in text
    assert f"s32[4,{params.window_len(24)}]" in text


def test_lowered_affine_has_dirs_output():
    text = to_hlo_text(lower_variant(affine_align, 2, 24))
    assert f"s32[2,24,{params.BAND}]" in text  # traceback tensor


def test_manifest_written(tmp_path):
    """aot.main writes one HLO file per variant + a coherent manifest."""
    import subprocess
    import sys

    env = dict(os.environ)
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--read-len", "24"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["read_len"] == 24
    assert manifest["band"] == params.BAND
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {
        f"linear_wf_b{b}" for b in params.LINEAR_BATCHES
    } | {f"affine_wf_b{b}" for b in params.AFFINE_BATCHES}
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert "HloModule" in text


def test_hlo_executes_on_cpu_pjrt_equivalently():
    """The lowered HLO text, recompiled through xla_client, must produce
    the same numbers as the traced graph — the same contract the Rust
    runtime relies on."""
    from jax._src.lib import xla_client as xc

    rng = np.random.default_rng(3)
    reads, wins = _mk(rng, 4)
    lowered = jax.jit(linear_filter).lower(
        jax.ShapeDtypeStruct(reads.shape, "int32"),
        jax.ShapeDtypeStruct(wins.shape, "int32"),
    )
    compiled = lowered.compile()
    want = [np.asarray(x) for x in compiled(reads, wins)]
    got = [np.asarray(x) for x in linear_filter(reads, wins)]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
