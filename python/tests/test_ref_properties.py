"""Properties of the serial oracles themselves (independent of the
kernels): DP laws that must hold for any correct Wagner-Fischer
implementation. These guard the oracle — if the oracle drifts, the
kernel parity tests would silently chase it.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.params import BAND, ETH, SAT_AFFINE, SAT_LINEAR, window_len

NS = (8, 16, 24)


def rand_pair(rng, n):
    return (
        rng.integers(0, 4, n).astype(np.int32),
        rng.integers(0, 4, window_len(n)).astype(np.int32),
    )


@settings(deadline=None, max_examples=40)
@given(n=st.sampled_from(NS), seed=st.integers(0, 2**32 - 1))
def test_band_values_in_range(n, seed):
    rng = np.random.default_rng(seed)
    read, win = rand_pair(rng, n)
    lin = ref.linear_wf_band(read, win)
    assert lin.min() >= 0 and lin.max() <= SAT_LINEAR
    aff, dirs = ref.affine_wf_band(read, win)
    assert aff.min() >= 0 and aff.max() <= SAT_AFFINE
    assert dirs.min() >= 0 and dirs.max() < 16


@settings(deadline=None, max_examples=40)
@given(n=st.sampled_from(NS), seed=st.integers(0, 2**32 - 1))
def test_extra_errors_never_decrease_distance(n, seed):
    """Monotonicity: corrupting one more window base inside the band
    cannot decrease the banded distance by more than ... it can decrease
    locally (a corruption may create a chance match elsewhere), but
    corrupting a base the read currently matches on the center diagonal
    increases or preserves the center-cell distance."""
    rng = np.random.default_rng(seed)
    read = rng.integers(0, 4, n).astype(np.int32)
    win = rng.integers(0, 4, window_len(n)).astype(np.int32)
    win[ETH : ETH + n] = read  # exact plant
    base = ref.linear_wf_band(read, win)
    assert base[ETH] == 0
    p = int(rng.integers(0, n))
    win2 = win.copy()
    win2[ETH + p] = (win2[ETH + p] + 1) % 4
    after = ref.linear_wf_band(read, win2)
    assert after[ETH] >= base[ETH]
    assert after[ETH] <= 2  # one corruption costs at most a sub (or gap pair)


@settings(deadline=None, max_examples=40)
@given(n=st.sampled_from(NS), seed=st.integers(0, 2**32 - 1))
def test_identical_strings_have_zero_center(n, seed):
    rng = np.random.default_rng(seed)
    read = rng.integers(0, 4, n).astype(np.int32)
    win = rng.integers(0, 4, window_len(n)).astype(np.int32)
    win[ETH : ETH + n] = read
    assert ref.linear_wf_band(read, win)[ETH] == 0
    band, _ = ref.affine_wf_band(read, win)
    assert band[ETH] == 0


@settings(deadline=None, max_examples=30)
@given(n=st.sampled_from(NS), seed=st.integers(0, 2**32 - 1))
def test_band_init_shape_preserved_for_empty_progress(n, seed):
    """Row 0 of the DP is |j - eth|; a fully-mismatching first character
    can only grow values (non-decreasing along rows)."""
    rng = np.random.default_rng(seed)
    read, win = rand_pair(rng, n)
    lin = ref.linear_wf_band(read, win, clamp=False)
    # all values within [0, n + eth] sanity envelope
    assert lin.min() >= 0
    assert lin.max() <= n + 2 * ETH + 1


@settings(deadline=None, max_examples=30)
@given(
    n=st.sampled_from(NS),
    shift=st.integers(0, 2 * ETH),
    seed=st.integers(0, 2**32 - 1),
)
def test_anchor_charge_is_exact_for_clean_shifts(n, shift, seed):
    """A clean placement at window offset s scores exactly |s - eth| on
    band diagonal s (the anchoring charge, nothing else)."""
    rng = np.random.default_rng(seed)
    read = rng.integers(0, 4, n).astype(np.int32)
    win = rng.integers(0, 4, window_len(n)).astype(np.int32)
    win[shift : shift + n] = read
    lin = ref.linear_wf_band(read, win)
    expect = min(abs(shift - ETH), SAT_LINEAR)
    assert lin[shift] <= expect
    aff, dirs = ref.affine_wf_band(read, win)
    assert aff[shift] <= min(abs(shift - ETH), SAT_AFFINE)
    # traceback from that diagonal reproduces the shift as j_end
    if aff[shift] < SAT_AFFINE and aff[shift] == abs(shift - ETH):
        ops, j_end = ref.traceback(dirs, shift)
        if ops == "=" * n:
            assert j_end == shift


@settings(deadline=None, max_examples=30)
@given(n=st.sampled_from(NS), seed=st.integers(0, 2**32 - 1))
def test_full_matrix_validator_agrees_with_rolling_on_affine_inputs(n, seed):
    """The independent full-matrix DP agrees with the rolling-buffer
    oracle on arbitrary inputs (not just planted ones)."""
    rng = np.random.default_rng(seed)
    read, win = rand_pair(rng, n)
    np.testing.assert_array_equal(
        ref.linear_wf_band(read, win), ref.linear_wf_full(read, win)
    )


def test_apply_script_rejects_wrong_length():
    import pytest

    with pytest.raises(AssertionError):
        ref.apply_script("==", 6, np.zeros(30, dtype=np.int64), 5)


def test_traceback_rejects_corrupt_dirs():
    import pytest

    n = 10
    dirs = np.full((n, BAND), 0b0100 | 2, dtype=np.int64)  # M1 extend forever
    with pytest.raises(ValueError):
        ref.traceback(dirs, ETH)
