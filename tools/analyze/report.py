"""Finding renderers: plain text, GitHub workflow commands, SARIF 2.1.0.

The text form (`path:line: [check] message`) is the contract pinned by
the test suite; the other two exist so CI can surface findings inline
on PRs (GitHub annotations) and archive them in a machine-readable run
log (SARIF) without changing the analyzer's exit-code semantics.
"""

from __future__ import annotations

import json

from . import config

_SARIF_VERSION = "2.1.0"
_SARIF_SCHEMA = (
    "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/"
    "Schemata/sarif-schema-2.1.0.json"
)

# One-line rule descriptions surfaced in SARIF viewers.
_RULE_DESCRIPTIONS = {
    "struct-exhaustive": "Struct literals of evolving structs must name every field.",
    "determinism": "Nondeterminism hazards reachable from byte-emitting sinks need proofs.",
    "flush-ack": "Ack-bearing protocol messages need a created channel and a reachable receive.",
    "enum-wildcard": "Matches on byte-affecting enums must not fall through a wildcard arm.",
    "metrics-registry": "Every Metrics counter must be registered in invariant_counters().",
    "unsafe": "unsafe code needs an adjacent SAFETY justification.",
    "msrv": "No std APIs newer than the pinned rust-version.",
    "line-length": "rustfmt max_width, enforced without rustfmt.",
    "pub-doc": "Public items need doc comments (missing_docs parity).",
    "cli-docs": "Every CLI flag must appear in the user documentation.",
    "annotation": "allow() annotations must name a check, give a reason, and stay live.",
}


def render_text(findings) -> str:
    return "\n".join(f.render() for f in findings)


def render_github(findings) -> str:
    """GitHub Actions workflow commands — one `::error` per finding.
    Messages must not contain the `::` command delimiters raw; GitHub
    requires percent-encoding of %, CR, LF in the message property."""
    lines = []
    for f in findings:
        msg = (
            f"[{f.check}] {f.message}"
            .replace("%", "%25")
            .replace("\r", "%0D")
            .replace("\n", "%0A")
        )
        lines.append(f"::error file={f.path},line={f.line}::{msg}")
    return "\n".join(lines)


def render_sarif(findings) -> str:
    rules = [
        {
            "id": name,
            "shortDescription": {"text": _RULE_DESCRIPTIONS.get(name, name)},
        }
        for name in tuple(config.ALL_CHECKS) + ("annotation",)
    ]
    results = [
        {
            "ruleId": f.check,
            "level": "error",
            "message": {"text": f.message},
            "locations": [
                {
                    "physicalLocation": {
                        "artifactLocation": {"uri": f.path},
                        "region": {"startLine": f.line},
                    }
                }
            ],
        }
        for f in findings
    ]
    doc = {
        "$schema": _SARIF_SCHEMA,
        "version": _SARIF_VERSION,
        "runs": [
            {
                "tool": {
                    "driver": {
                        "name": "dart-analyze",
                        "rules": rules,
                    }
                },
                "results": results,
            }
        ],
    }
    return json.dumps(doc, indent=2, sort_keys=True)


RENDERERS = {
    "text": render_text,
    "github": render_github,
    "sarif": render_sarif,
}
