//! Clean counterexample: the public item carries a doc comment
//! (pub-doc).

/// Returns the answer used by the fixture tests.
pub fn documented() -> u32 {
    7
}
