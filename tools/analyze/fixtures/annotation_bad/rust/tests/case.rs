//! Planted violations: an unknown check name, an empty reason, and a
//! stale allow that suppresses nothing (annotation).

// dart-analyze: allow(no-such-check): not a real check name.
fn one() -> u32 {
    1
}

// dart-analyze: allow(unsafe):
fn two() -> u32 {
    2
}

// dart-analyze: allow(msrv): nothing on the next line needs this.
fn three() -> u32 {
    3
}

fn main() {
    let _ = one() + two() + three();
}
