//! Planted violation: an undocumented `pub` item in the library tree
//! (pub-doc).

pub fn undocumented() -> u32 {
    7
}
