//! Clean counterexample: the discharged precondition is stated next to
//! the `unsafe` block (unsafe).

fn read_raw(v: &u32) -> u32 {
    let p = v as *const u32;
    // SAFETY: `p` was created from a live shared reference one line
    // above, so it is valid, aligned, and initialized for this read.
    unsafe { *p }
}

fn main() {
    let _ = read_raw(&7);
}
