//! Clean counterexamples: every variant named; the frame-kind wildcard
//! fails loudly; one wildcard carries an annotation with its reason.

enum EngineKind {
    Rust,
    Bitpal,
}

const KIND_DATA: u8 = 1;
const KIND_FINISH: u8 = 2;

fn width(kind: &EngineKind) -> u64 {
    match kind {
        EngineKind::Bitpal => 64,
        EngineKind::Rust => 0,
    }
}

fn on_frame(kind: u8) -> u32 {
    match kind {
        KIND_DATA => 1,
        KIND_FINISH => 2,
        other => panic!("unknown frame kind {other}"),
    }
}

fn label(kind: &EngineKind) -> &'static str {
    match kind {
        EngineKind::Bitpal => "bitpal",
        // dart-analyze: allow(enum-wildcard): label is a log-only
        // string; a new variant falling through to "other" cannot
        // change mapping bytes.
        _ => "other",
    }
}
