//! Clean counterexample: every line fits the budget (line-length).

fn main() {
    // short and within budget
}
