//! Clean counterexample: the same hazard exists, but no byte-emitting
//! sink can reach it — `count` is called only by a diagnostics helper
//! that `cmd_map` never calls, so taint reachability stays empty.

use std::collections::HashMap;

fn cmd_map() {
    println!("mapped");
}

fn debug_histogram(keys: &[u64]) -> usize {
    count(keys)
}

fn count(keys: &[u64]) -> usize {
    let mut m: HashMap<u64, u32> = HashMap::new();
    for &k in keys {
        *m.entry(k).or_insert(0) += 1;
    }
    m.len()
}
