//! Clean counterexample: the hazard carries an annotation with a
//! written proof at its first use (determinism).

// dart-analyze: allow(determinism): the map is keyed-access only and
// never iterated, so its order cannot reach emitted bytes.
use std::collections::HashMap;

fn count(keys: &[u64]) -> usize {
    let mut m: HashMap<u64, u32> = HashMap::new();
    for &k in keys {
        *m.entry(k).or_insert(0) += 1;
    }
    m.len()
}

fn main() {
    let _ = count(&[1, 2, 2]);
}
