//! Planted violation: a flag parsed here but absent from the
//! documentation files (cli-docs).

const USAGE: &str = "dart-pim frob --frobnicate";

fn main() {
    let _ = USAGE;
}
