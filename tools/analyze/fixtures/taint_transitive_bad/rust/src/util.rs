//! The callee is dirty: it iterates a HashSet, so the emission order
//! printed by `cmd_map` depends on hash state two hops away.

use std::collections::HashSet;

fn dedup_order(keys: &[u64]) -> Vec<u64> {
    let mut seen: HashSet<u64> = HashSet::new();
    for &k in keys {
        seen.insert(k);
    }
    seen.iter().copied().collect()
}
