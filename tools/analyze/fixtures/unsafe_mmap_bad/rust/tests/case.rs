//! Planted violation: an mmap-style FFI binding whose unsafe call
//! sites carry no adjacent `// SAFETY:` comments (unsafe).

extern "C" {
    fn mmap(addr: usize, len: usize, prot: i32, flags: i32, fd: i32, off: i64) -> usize;
    fn munmap(addr: usize, len: usize) -> i32;
}

fn map_file(fd: i32, len: usize) -> &'static [u8] {
    let p = unsafe { mmap(0, len, 1, 2, fd, 0) };
    unsafe { std::slice::from_raw_parts(p as *const u8, len) }
}

fn main() {
    let _ = map_file(0, 8);
    let _ = munmap as *const ();
}
