//! Planted violation: a `SimCounts` literal that omits a declared
//! field without a `..` base (struct-exhaustive).

struct SimCounts {
    reads: u64,
    pairs: u64,
}

fn mk() -> SimCounts {
    SimCounts { reads: 0 }
}

fn main() {
    let _ = mk();
}
