//! Planted violation: an API stabilized after the pinned MSRV (msrv).

fn check(v: Option<u32>) -> bool {
    v.is_none_or(|x| x > 0)
}

fn main() {
    let _ = check(None);
}
