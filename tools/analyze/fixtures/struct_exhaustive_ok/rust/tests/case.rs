//! Clean counterexample: exhaustive and `..`-based `SimCounts`
//! literals (struct-exhaustive).

struct SimCounts {
    reads: u64,
    pairs: u64,
}

fn mk() -> SimCounts {
    SimCounts { reads: 0, pairs: 0 }
}

fn bump() -> SimCounts {
    SimCounts { reads: 1, ..mk() }
}

fn main() {
    let _ = bump();
}
