//! Clean counterexample: the parsed flag is documented (cli-docs).

const USAGE: &str = "dart-pim frob --frobnicate";

fn main() {
    let _ = USAGE;
}
