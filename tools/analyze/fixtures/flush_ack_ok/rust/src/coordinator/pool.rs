//! Clean counterexample: the ack channel is created in the sending fn
//! and the receive is reachable (one call away), so the epoch barrier
//! closes; every variant is both sent and handled.

use std::sync::mpsc;
use std::time::Duration;

enum PoolMsg {
    Items { n: u32 },
    Flush { ack: mpsc::Sender<u32> },
}

fn push(tx: &mpsc::Sender<PoolMsg>) {
    let _ = tx.send(PoolMsg::Items { n: 1 });
}

fn flush(tx: &mpsc::Sender<PoolMsg>) {
    let (ack_tx, ack_rx) = mpsc::channel();
    let _ = tx.send(PoolMsg::Flush { ack: ack_tx });
    wait_ack(ack_rx);
}

fn wait_ack(rx: mpsc::Receiver<u32>) {
    let _ = rx.recv_timeout(Duration::from_secs(1));
}

fn worker(rx: mpsc::Receiver<PoolMsg>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            PoolMsg::Items { n } => drop(n),
            PoolMsg::Flush { ack } => {
                let _ = ack.send(1);
            }
        }
    }
}
