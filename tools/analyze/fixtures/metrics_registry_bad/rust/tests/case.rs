//! Planted violation: a `Metrics` counter field absent from
//! `invariant_counters()` and unannotated (metrics-registry).

use std::collections::BTreeMap;

struct Metrics {
    mapped: u64,
    dropped: u64,
}

impl Metrics {
    fn invariant_counters(&self) -> BTreeMap<&'static str, u64> {
        BTreeMap::from([("mapped", self.mapped)])
    }
}

fn main() {
    let m = Metrics { mapped: 0, dropped: 0 };
    let _ = m.invariant_counters();
}
