//! Clean counterexample: every counter is registered or annotated as a
//! deliberate exclusion (metrics-registry).

use std::collections::BTreeMap;

struct Metrics {
    mapped: u64,
    // dart-analyze: allow(metrics-registry): a gauge describing the
    // run configuration, not a workload invariant (invariant 4).
    simd_width: u64,
}

impl Metrics {
    fn invariant_counters(&self) -> BTreeMap<&'static str, u64> {
        BTreeMap::from([("mapped", self.mapped)])
    }
}

fn main() {
    let m = Metrics { mapped: 0, simd_width: 0 };
    let _ = m.invariant_counters();
}
