//! Clean counterexample: the MSRV-compatible spelling (msrv).

fn check(v: Option<u32>) -> bool {
    v.map_or(true, |x| x > 0)
}

fn main() {
    let _ = check(None);
}
