//! Planted protocol violations: a Flush whose ack is never received,
//! and a Close variant that exists only on paper.

use std::sync::mpsc;

enum PoolMsg {
    Items { n: u32 },
    Flush { ack: mpsc::Sender<u32> },
    Close { ack: mpsc::Sender<u32> },
}

fn flush(tx: &mpsc::Sender<PoolMsg>) {
    let (ack_tx, _ack_rx) = mpsc::channel();
    let _ = tx.send(PoolMsg::Flush { ack: ack_tx });
    // the barrier never completes: _ack_rx is dropped unread
}

fn worker(rx: mpsc::Receiver<PoolMsg>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            PoolMsg::Items { n } => drop(n),
            PoolMsg::Flush { ack } => {
                let _ = ack.send(1);
            }
        }
    }
}
