//! Same shape as taint_transitive_bad, but the hazard carries a proof:
//! the set is drained through a sort, so hash order never escapes.

use std::collections::HashSet;

fn dedup_order(keys: &[u64]) -> Vec<u64> {
    // dart-analyze: allow(determinism): membership dedup only; the
    // collected vector is sorted before returning, so hash order is
    // unobservable downstream.
    let mut seen: HashSet<u64> = HashSet::new();
    for &k in keys {
        seen.insert(k);
    }
    let mut out: Vec<u64> = seen.iter().copied().collect();
    out.sort_unstable();
    out
}
