//! The caller is spotless: no hazard identifier appears anywhere in
//! this file, and it is not in a "byte-producing" directory list — the
//! pre-semantic per-file grep had nothing to flag here.

mod util;

fn cmd_map() {
    let order = crate::util::dedup_order(&[3, 1, 3]);
    for v in order {
        println!("{v}");
    }
}
