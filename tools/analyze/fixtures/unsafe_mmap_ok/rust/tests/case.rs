//! Clean counterexample: the same mmap binding with every unsafe call
//! site's precondition discharged right next to it (unsafe).

extern "C" {
    fn mmap(addr: usize, len: usize, prot: i32, flags: i32, fd: i32, off: i64) -> usize;
    fn munmap(addr: usize, len: usize) -> i32;
}

fn map_file(fd: i32, len: usize) -> &'static [u8] {
    // SAFETY: addr 0 lets the kernel pick the placement; fd is the
    // caller's live descriptor and the result is checked before use.
    let p = unsafe { mmap(0, len, 1, 2, fd, 0) };
    assert!(p != usize::MAX, "mmap failed");
    // SAFETY: `p` is a page-aligned read-only mapping of exactly `len`
    // bytes; it is never unmapped, so the 'static borrow stays valid.
    unsafe { std::slice::from_raw_parts(p as *const u8, len) }
}

fn main() {
    let _ = map_file(0, 8);
    let _ = munmap as *const ();
}
