//! Planted violation: a line wider than rustfmt's max_width (line-length).

fn main() {
    // planted: padding padding padding padding padding padding padding padding padding padding padding
}
