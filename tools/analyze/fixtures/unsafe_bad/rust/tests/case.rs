//! Planted violation: an `unsafe` block with no adjacent `// SAFETY:`
//! comment (unsafe).

fn read_raw(v: &u32) -> u32 {
    let p = v as *const u32;
    unsafe { *p }
}

fn main() {
    let _ = read_raw(&7);
}
