//! Planted violations: a byte-affecting enum matched with a silent
//! wildcard, and a frame-kind match that absorbs unknown kinds.

enum EngineKind {
    Rust,
    Bitpal,
}

const KIND_DATA: u8 = 1;
const KIND_FINISH: u8 = 2;

fn width(kind: &EngineKind) -> u64 {
    match kind {
        EngineKind::Bitpal => 64,
        _ => 0,
    }
}

fn on_frame(kind: u8) -> u32 {
    match kind {
        KIND_DATA => 1,
        KIND_FINISH => 2,
        _ => 0,
    }
}
