//! Planted violation: an unannotated `HashMap` in a byte-producing
//! module (determinism).

use std::collections::HashMap;

fn count(keys: &[u64]) -> usize {
    let mut m: HashMap<u64, u32> = HashMap::new();
    for &k in keys {
        *m.entry(k).or_insert(0) += 1;
    }
    m.len()
}

fn main() {
    let _ = count(&[1, 2, 2]);
}
