//! Planted violation: a hash-iteration hazard inside a fn that a
//! byte-emitting sink (`cmd_map`) calls directly.

use std::collections::HashMap;

fn cmd_map() {
    let n = count(&[1, 2, 2]);
    println!("{n}");
}

fn count(keys: &[u64]) -> usize {
    let mut m: HashMap<u64, u32> = HashMap::new();
    for &k in keys {
        *m.entry(k).or_insert(0) += 1;
    }
    m.len()
}
