"""Entry point: ``python3 -m tools.analyze`` (see package docs)."""

import sys

from .runner import main

if __name__ == "__main__":
    sys.exit(main())
