"""Item-level symbol table: the parser half of the semantic pass.

One linear walk over a file's token stream recovers the structure the
interprocedural checks need — `fn` items with body spans and their
`mod`/`impl` context, `enum` declarations with variants and variant
fields, `struct` fields with their type identifiers, `use` aliases,
and `match` expressions with per-arm pattern/body spans.

This is deliberately not a full Rust parser. It tracks exactly the
bracket/angle structure needed to find item boundaries, and it
over-approximates everywhere a real compiler would disambiguate
(macro bodies are plain tokens, generics are skipped, patterns are
token slices). The call graph built on top (`callgraph.py`) inherits
that over-approximation, which is the safe direction for a checker:
extra edges can only make a hazard *look* reachable, never hide one.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .lexer import Tok
from .model import SourceFile

# Keywords that look like `ident (` call sites but are not calls, plus
# everything that can never name a fn item.
RUST_KEYWORDS = {
    "as", "async", "await", "box", "break", "const", "continue", "crate",
    "dyn", "else", "enum", "extern", "fn", "for", "if", "impl", "in",
    "let", "loop", "match", "mod", "move", "mut", "pub", "ref", "return",
    "self", "Self", "static", "struct", "super", "trait", "type",
    "union", "unsafe", "use", "where", "while", "yield",
}


def _angle_delta(text: str) -> int:
    return {"<": 1, "<<": 2, ">": -1, ">>": -2}.get(text, 0)


def _skip_attr(sf: SourceFile, i: int) -> int:
    """Index just past an attribute starting at ``i``, else ``i``."""
    toks = sf.tokens
    j = i
    if j < len(toks) and toks[j].text == "#":
        j += 1
        if j < len(toks) and toks[j].text == "!":
            j += 1
        if j < len(toks) and toks[j].text == "[":
            return sf._match(j, "[", "]") + 1
    return i


@dataclass
class FnItem:
    """One ``fn`` item (free fn, method, trait default, or nested fn)."""

    path: str
    name: str
    line: int  # line of the `fn` keyword
    qual: tuple  # in-crate module path, e.g. ("coordinator", "pool")
    self_type: str | None  # impl/trait type, None for free and nested fns
    fn_tok: int  # token index of the `fn` keyword
    body: tuple[int, int]  # token range (open+1, close) of the body, (-1, -1) if none
    nested: list = field(default_factory=list)  # full token ranges of nested fn items

    @property
    def key(self) -> tuple[str, int]:
        return (self.path, self.fn_tok)

    def own_ranges(self) -> list[tuple[int, int]]:
        """Body token ranges minus nested ``fn`` items. Closures stay:
        a closure's effects belong to the fn that runs or spawns it."""
        lo, hi = self.body
        if lo < 0:
            return []
        out, cur = [], lo
        for nlo, nhi in sorted(self.nested):
            if nlo > cur:
                out.append((cur, nlo))
            cur = max(cur, nhi)
        if cur < hi:
            out.append((cur, hi))
        return out


@dataclass
class Variant:
    name: str
    line: int
    fields: tuple  # record-variant field names, () for tuple/unit


@dataclass
class EnumItem:
    path: str
    name: str
    line: int
    variants: list


@dataclass
class StructItem:
    path: str
    name: str
    line: int
    fields: list  # (field name, tuple of type identifier texts, line)


@dataclass
class MatchArm:
    line: int
    pat: tuple[int, int]  # token range of the pattern (guard excluded)
    body: tuple[int, int]  # token range of the arm body
    has_guard: bool


@dataclass
class MatchExpr:
    line: int
    arms: list


@dataclass
class FileItems:
    """Everything `parse_file` recovers from one source file."""

    path: str
    fns: list = field(default_factory=list)
    enums: list = field(default_factory=list)
    structs: list = field(default_factory=list)
    uses: dict = field(default_factory=dict)  # leaf/alias -> full path segments
    use_ranges: list = field(default_factory=list)  # token ranges of `use` items
    matches: list = field(default_factory=list)

    def in_use_item(self, idx: int) -> bool:
        return any(lo <= idx < hi for lo, hi in self.use_ranges)

    def pattern_spans(self) -> list[tuple[int, int]]:
        return [arm.pat for m in self.matches for arm in m.arms]


def file_qual(path: str) -> tuple:
    """In-crate module path of a lib file:
    ``rust/src/coordinator/pool.rs`` -> ``("coordinator", "pool")``."""
    if not path.startswith("rust/src/"):
        return ()
    parts = path[len("rust/src/"):].removesuffix(".rs").split("/")
    if parts and parts[-1] in ("lib", "main", "mod"):
        parts = parts[:-1]
    return tuple(parts)


def parse_file(sf: SourceFile) -> FileItems:
    """One pass: fns (with scope context), enums, structs, uses, matches."""
    toks = sf.tokens
    n = len(toks)
    fi = FileItems(path=sf.path)
    base = file_qual(sf.path)
    mods: list[tuple[int, str]] = []  # (close token index, mod name)
    impls: list[tuple[int, str | None]] = []  # (close, self type)
    fn_stack: list[tuple[int, FnItem]] = []  # (body close, enclosing fn)
    i = 0
    while i < n:
        while mods and i > mods[-1][0]:
            mods.pop()
        while impls and i > impls[-1][0]:
            impls.pop()
        while fn_stack and i > fn_stack[-1][0]:
            fn_stack.pop()
        t = toks[i]
        if t.text == "#":
            i = max(i + 1, _skip_attr(sf, i))
            continue
        if t.kind != "ident":
            i += 1
            continue
        if t.text == "use":
            j = i + 1
            while j < n and toks[j].text != ";":
                j += 1
            fi.use_ranges.append((i, j + 1))
            _use_tree(toks, i + 1, j, [], fi.uses)
            i = j + 1
            continue
        if t.text == "mod" and i + 1 < n and toks[i + 1].kind == "ident":
            if i + 2 < n and toks[i + 2].text == "{":
                mods.append((sf._match(i + 2, "{", "}"), toks[i + 1].text))
                i += 3
                continue
            i += 2
            continue
        if t.text in ("impl", "trait"):
            scope = _impl_scope(sf, i)
            if scope is not None:
                close, self_type, open_idx = scope
                impls.append((close, self_type))
                i = open_idx + 1
                continue
            i += 1
            continue
        if t.text == "fn" and i + 1 < n and toks[i + 1].kind == "ident":
            item = _fn_item(sf, i, base, mods, impls, fn_stack)
            fi.fns.append(item)
            if item.body[0] >= 0:
                if fn_stack:
                    fn_stack[-1][1].nested.append((i, item.body[1] + 1))
                fn_stack.append((item.body[1], item))
                i = item.body[0]
                continue
            i += 2
            continue
        if t.text == "enum" and i + 1 < n and toks[i + 1].kind == "ident":
            item = _enum_item(sf, i)
            if item is not None:
                fi.enums.append(item)
        if t.text == "struct" and i + 1 < n and toks[i + 1].kind == "ident":
            item = _struct_item(sf, i)
            if item is not None:
                fi.structs.append(item)
        if t.text == "match":
            m = _match_expr(sf, i)
            if m is not None:
                fi.matches.append(m)
        i += 1
    return fi


# -- item sub-parsers --------------------------------------------------


def _use_tree(toks, lo, hi, prefix, out) -> None:
    """Aliases declared by one use tree: leaf (or `as` name) -> path."""
    segs = list(prefix)
    alias = None
    i = lo
    while i < hi:
        tx = toks[i].text
        if tx == "{":
            close = _slice_match(toks, i, hi)
            for clo, chi in _split_commas(toks, i + 1, close):
                _use_tree(toks, clo, chi, segs, out)
            return
        if tx == "as":
            alias = toks[i + 1].text if i + 1 < hi else None
            i += 2
            continue
        if tx == "*":
            return  # glob: contributes no resolvable alias
        if toks[i].kind == "ident":
            segs.append(tx)
        i += 1
    if segs and segs[-1] == "self":
        segs.pop()
    name = alias or (segs[-1] if segs else None)
    if name and name != "_":
        out[name] = tuple(segs)


def _slice_match(toks, i_open, hi) -> int:
    depth = 0
    for j in range(i_open, hi):
        if toks[j].text == "{":
            depth += 1
        elif toks[j].text == "}":
            depth -= 1
            if depth == 0:
                return j
    return hi


def _split_commas(toks, lo, hi):
    depth = 0
    cur = lo
    for j in range(lo, hi):
        tx = toks[j].text
        if tx in "([{":
            depth += 1
        elif tx in ")]}":
            depth -= 1
        elif tx == "," and depth == 0:
            yield (cur, j)
            cur = j + 1
    if cur < hi:
        yield (cur, hi)


def _impl_scope(sf, i):
    """``(body close, self type, body open)`` of an impl/trait block, or
    None for `impl Trait` in type position etc. Self type: the last
    angle-depth-0 identifier after the last top-level `for` (so
    `impl fmt::Display for Metrics` and `impl Metrics` both yield
    `Metrics`; a trait block yields the trait name)."""
    toks = sf.tokens
    n = len(toks)
    j = i + 1
    angle = 0
    last_ident = None
    while j < n:
        tx = toks[j].text
        angle += _angle_delta(tx)
        if tx == "{" and angle <= 0:
            break
        if tx == ";" and angle <= 0:
            return None
        if angle <= 0:
            if tx == "for":
                last_ident = None
            elif toks[j].kind == "ident" and tx not in RUST_KEYWORDS:
                last_ident = tx
            elif tx == "Self":
                last_ident = tx
        j += 1
    if j >= n:
        return None
    return (sf._match(j, "{", "}"), last_ident, j)


def _fn_item(sf, i, base, mods, impls, fn_stack) -> FnItem:
    toks = sf.tokens
    n = len(toks)
    name = toks[i + 1].text
    j = i + 2
    depth = angle = 0
    body = (-1, -1)
    while j < n:
        tx = toks[j].text
        if tx in "([":
            depth += 1
        elif tx in ")]":
            depth -= 1
        elif depth == 0:
            angle += _angle_delta(tx)
            if tx == "{" and angle <= 0:
                body = (j + 1, sf._match(j, "{", "}"))
                break
            if tx == ";" and angle <= 0:
                break
        j += 1
    # a nested fn is a free fn even inside an impl method
    self_type = impls[-1][1] if impls and not fn_stack else None
    return FnItem(
        path=sf.path,
        name=name,
        line=toks[i].line,
        qual=base + tuple(m[1] for m in mods),
        self_type=self_type,
        fn_tok=i,
        body=body,
    )


def _enum_item(sf, i):
    toks = sf.tokens
    n = len(toks)
    name = toks[i + 1].text
    j = i + 2
    angle = 0
    while j < n:
        tx = toks[j].text
        angle += _angle_delta(tx)
        if tx == "{" and angle <= 0:
            break
        if tx == ";" and angle <= 0:
            return None
        j += 1
    if j >= n:
        return None
    close = sf._match(j, "{", "}")
    variants = []
    k = j + 1
    while k < close:
        k = _skip_attr(sf, k)
        if k >= close or toks[k].kind != "ident":
            k += 1
            continue
        v = Variant(name=toks[k].text, line=toks[k].line, fields=())
        k += 1
        if k < close and toks[k].text == "{":
            vclose = sf._match(k, "{", "}")
            names = []
            m = k + 1
            while m < vclose:
                m = _skip_attr(sf, m)
                if (
                    m + 1 < vclose
                    and toks[m].kind == "ident"
                    and toks[m + 1].text == ":"
                ):
                    names.append(toks[m].text)
                    # skip the field type to the next top-level comma
                    d = 0
                    while m < vclose:
                        tx = toks[m].text
                        if tx in "([{":
                            d += 1
                        elif tx in ")]}":
                            d -= 1
                        if tx == "," and d == 0:
                            break
                        m += 1
                m += 1
            v = Variant(name=v.name, line=v.line, fields=tuple(names))
            k = vclose + 1
        elif k < close and toks[k].text == "(":
            k = sf._match(k, "(", ")") + 1
        variants.append(v)
        while k < close and toks[k].text != ",":  # skip `= disc`
            k += 1
        k += 1
    return EnumItem(path=sf.path, name=name, line=toks[i].line, variants=variants)


def _struct_item(sf, i):
    toks = sf.tokens
    n = len(toks)
    name = toks[i + 1].text
    j = i + 2
    angle = 0
    while j < n:
        tx = toks[j].text
        angle += _angle_delta(tx)
        if tx == "{" and angle <= 0:
            break
        if tx in (";", "(") and angle <= 0:
            return StructItem(path=sf.path, name=name, line=toks[i].line, fields=[])
        j += 1
    if j >= n:
        return None
    close = sf._match(j, "{", "}")
    fields = []
    k = j + 1
    while k < close:
        k = _skip_attr(sf, k)
        if k >= close:
            break
        if toks[k].text == "pub":
            k += 1
            if k < close and toks[k].text == "(":
                k = sf._match(k, "(", ")") + 1
        if k + 1 < close and toks[k].kind == "ident" and toks[k + 1].text == ":":
            fname, fline = toks[k].text, toks[k].line
            type_idents = []
            d = 0
            m = k + 2
            while m < close:
                tx = toks[m].text
                if tx in "([{":
                    d += 1
                elif tx in ")]}":
                    d -= 1
                if tx == "," and d == 0:
                    break
                if toks[m].kind == "ident":
                    type_idents.append(tx)
                m += 1
            fields.append((fname, tuple(type_idents), fline))
            k = m + 1
            continue
        k += 1
    return StructItem(path=sf.path, name=name, line=toks[i].line, fields=fields)


def _match_expr(sf, i):
    """Parse ``match scrutinee { arms }`` starting at the ``match``
    keyword; None if no arm block is found (e.g. a `match` path seg)."""
    toks = sf.tokens
    n = len(toks)
    j = i + 1
    depth = 0
    while j < n:
        tx = toks[j].text
        if tx == "{" and depth == 0:
            break
        if tx in "([{":
            depth += 1
        elif tx in ")]}":
            depth -= 1
        elif tx == ";" and depth == 0:
            return None
        j += 1
    if j >= n or j == i + 1:
        return None
    close = sf._match(j, "{", "}")
    arms = []
    k = j + 1
    while k < close:
        k = _skip_attr(sf, k)
        if k >= close:
            break
        pat_lo = k
        guard_at = -1
        d = 0
        while k < close:
            tx = toks[k].text
            if tx == "=>" and d == 0:
                break
            if tx in "([{":
                d += 1
            elif tx in ")]}":
                d -= 1
            elif tx == "if" and d == 0 and guard_at < 0:
                guard_at = k
            k += 1
        if k >= close:
            break
        pat_hi = guard_at if guard_at >= 0 else k
        body_lo = k + 1
        if body_lo < close and toks[body_lo].text == "{":
            body_hi = sf._match(body_lo, "{", "}") + 1
            k = body_hi
            if k < close and toks[k].text == ",":
                k += 1
        else:
            d = 0
            k = body_lo
            while k < close:
                tx = toks[k].text
                if tx == "," and d == 0:
                    break
                if tx in "([{":
                    d += 1
                elif tx in ")]}":
                    d -= 1
                k += 1
            body_hi = k
            k += 1
        arms.append(
            MatchArm(
                line=toks[pat_lo].line,
                pat=(pat_lo, pat_hi),
                body=(body_lo, body_hi),
                has_guard=guard_at >= 0,
            )
        )
    return MatchExpr(line=toks[i].line, arms=arms)
