"""Repository-specific configuration for the analysis pass.

Everything path-like is repo-root-relative with ``/`` separators. The
same configuration drives the fixture corpus: a fixture directory is a
miniature repository, so paths resolve identically there.
"""

# Directories scanned for Rust sources (recursive), and subtrees never
# scanned. `rust/vendor` holds third-party API subsets we deliberately
# do not hold to this repo's conventions.
SCAN_DIRS = ("rust/src", "rust/tests", "rust/benches", "examples")
EXCLUDE_DIRS = ("rust/vendor",)

# Checks ---------------------------------------------------------------

# Structs whose literal-construction sites must be exhaustive. These are
# the structs that have historically grown fields (the PR 5 `SimCounts`
# E0063 break) and are constructed far from their declarations.
EXHAUSTIVE_STRUCTS = ("Metrics", "SimCounts")

# Modules whose output feeds emitted bytes (mapping TSVs, serve replies,
# golden fixtures). Determinism hazards inside these need a written
# proof; metrics/bench/signal code earns its annotation, it is not
# exempted wholesale.
BYTE_PRODUCING_DIRS = (
    "rust/src/coordinator",
    "rust/src/serve",
    "rust/src/align",
    "rust/src/runtime",
    "rust/src/index",
    "rust/src/seeding",
)

# Hazard categories for the determinism check: category -> identifiers.
# The first non-test occurrence per (file, category) is the gate: the
# annotation (and its proof) lives there and covers the file, keeping
# the audit in one greppable place instead of smeared over every use.
DETERMINISM_HAZARDS = {
    "hash-iteration": ("HashMap", "HashSet"),
    "wall-clock": ("Instant", "SystemTime"),
    "unseeded-rng": (
        "thread_rng",
        "ThreadRng",
        "from_entropy",
        "OsRng",
        "RandomState",
        "getrandom",
    ),
}

# std APIs stabilized after rust-version = "1.74" (rust/Cargo.toml) that
# have drifted into review before. Identifier -> version it needs.
# Extend this list whenever a compile review catches a new one.
MSRV = "1.74"
MSRV_DENYLIST = {
    "is_none_or": "1.82",
    "is_sorted": "1.82",
    "is_sorted_by": "1.82",
    "is_sorted_by_key": "1.82",
    "take_if": "1.80",
    "LazyLock": "1.80",
    "LazyCell": "1.80",
    "trim_ascii": "1.80",
    "trim_ascii_start": "1.80",
    "trim_ascii_end": "1.80",
    "isqrt": "1.84",
    "midpoint": "1.85",
    "pop_if": "1.86",
    "first_chunk": "1.77",
    "last_chunk": "1.77",
    "split_first_chunk": "1.77",
    "split_last_chunk": "1.77",
}

# rustfmt's max_width, enforceable without rustfmt.
MAX_WIDTH = 100

# pub-doc only applies to the library source tree (mirrors the
# missing_docs lint + RUSTDOCFLAGS=-D warnings CI docs job).
PUB_DOC_DIRS = ("rust/src",)

# cli-docs: flag strings found in this file must appear in one of the
# documentation files.
CLI_FILE = "rust/src/cli.rs"
CLI_DOC_FILES = ("README.md", "SERVING.md")

# Fields of these Rust types are exempt from the metrics-registry check:
# they are wall-clock aggregates, not workload counters, and invariant 4
# excludes them by design.
METRICS_TIMING_TYPES = ("Duration",)

ALL_CHECKS = (
    "struct-exhaustive",
    "determinism",
    "metrics-registry",
    "unsafe",
    "msrv",
    "line-length",
    "pub-doc",
    "cli-docs",
)
