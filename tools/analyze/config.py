"""Repository-specific configuration for the analysis pass.

Everything path-like is repo-root-relative with ``/`` separators. The
same configuration drives the fixture corpus: a fixture directory is a
miniature repository, so paths resolve identically there.
"""

# Directories scanned for Rust sources (recursive), and subtrees never
# scanned. `rust/vendor` holds third-party API subsets we deliberately
# do not hold to this repo's conventions.
SCAN_DIRS = ("rust/src", "rust/tests", "rust/benches", "examples")
EXCLUDE_DIRS = ("rust/vendor",)

# Checks ---------------------------------------------------------------

# Structs whose literal-construction sites must be exhaustive. These are
# the structs that have historically grown fields (the PR 5 `SimCounts`
# E0063 break) and are constructed far from their declarations.
EXHAUSTIVE_STRUCTS = ("Metrics", "SimCounts")

# Byte-emitting sinks for the determinism taint check: (path, fn name).
# Taint = reachability in the call graph: a hazard matters iff some
# sink can reach the fn using it. This replaced the per-directory grep
# (BYTE_PRODUCING_DIRS) in PR 9 — scope is now "reachable from an emit
# site", wherever the file lives.
TAINT_SINKS = (
    ("rust/src/cli.rs", "cmd_map"),
    ("rust/src/cli.rs", "write_tsv_header"),
    ("rust/src/cli.rs", "write_tsv_row"),
    ("rust/src/coordinator/pipeline.rs", "emit_epoch"),
    ("rust/src/serve/conn.rs", "handle_connection"),
    ("rust/src/serve/conn.rs", "run_session"),
    ("rust/src/serve/conn.rs", "metrics_line"),
    # The DARTPIM2 writers: on-disk index bytes are output bytes too —
    # both builders must emit identical files for identical inputs
    # (invariant 9), so map-order hazards reaching them are findings.
    ("rust/src/index/v2.rs", "write_index_v2"),
    ("rust/src/index/v2.rs", "write_index_v2_streaming"),
)

# Hazard categories for the determinism check: category -> identifiers.
# The first occurrence per (file, category) that is reachable from a
# sink is the gate: the annotation (and its proof) lives there — or on
# the enclosing fn, or on a hazard-typed field's declaration — and
# covers the file, keeping the audit in one greppable place instead of
# smeared over every use.
DETERMINISM_HAZARDS = {
    "hash-iteration": ("HashMap", "HashSet"),
    "wall-clock": ("Instant", "SystemTime"),
    "unseeded-rng": (
        "thread_rng",
        "ThreadRng",
        "from_entropy",
        "OsRng",
        "RandomState",
        "getrandom",
    ),
    # Host-dependent gauges: values that vary with the machine (SIMD
    # width, feature detection) and must never steer emitted bytes.
    "host-gauge": ("simd_width", "detect_wide", "is_x86_feature_detected"),
}

# std APIs stabilized after rust-version = "1.74" (rust/Cargo.toml) that
# have drifted into review before. Identifier -> version it needs.
# Extend this list whenever a compile review catches a new one.
MSRV = "1.74"
MSRV_DENYLIST = {
    "is_none_or": "1.82",
    "is_sorted": "1.82",
    "is_sorted_by": "1.82",
    "is_sorted_by_key": "1.82",
    "take_if": "1.80",
    "LazyLock": "1.80",
    "LazyCell": "1.80",
    "trim_ascii": "1.80",
    "trim_ascii_start": "1.80",
    "trim_ascii_end": "1.80",
    "isqrt": "1.84",
    "midpoint": "1.85",
    "pop_if": "1.86",
    "first_chunk": "1.77",
    "last_chunk": "1.77",
    "split_first_chunk": "1.77",
    "split_last_chunk": "1.77",
}

# rustfmt's max_width, enforceable without rustfmt.
MAX_WIDTH = 100

# pub-doc only applies to the library source tree (mirrors the
# missing_docs lint + RUSTDOCFLAGS=-D warnings CI docs job).
PUB_DOC_DIRS = ("rust/src",)

# cli-docs: flag strings found in this file must appear in one of the
# documentation files.
CLI_FILE = "rust/src/cli.rs"
CLI_DOC_FILES = ("README.md", "SERVING.md")

# Fields of these Rust types are exempt from the metrics-registry check:
# they are wall-clock aggregates, not workload counters, and invariant 4
# excludes them by design.
METRICS_TIMING_TYPES = ("Duration",)

# flush-ack: identifiers that constitute "receiving an ack" / "creating
# the ack channel". An enum variant carrying a field literally named
# `ack` is treated as an ack-bearing protocol message.
RECV_IDENTS = ("recv", "recv_timeout", "try_recv", "recv_deadline")
CHANNEL_IDENTS = ("channel", "sync_channel")

# enum-wildcard: matching these byte-affecting enums with a `_` (or
# bare-binding) arm is a silent-fallthrough hazard. A match over DART/1
# frame-kind constants (the `KIND_*` u8 group) may keep its wildcard
# only if the arm is loud (error/panic), since u8 is never exhaustive.
WILDCARD_ENUMS = (
    "PairStatus",
    "EngineKind",
    "SimdMode",
    "PoolMsg",
    "Mode",
    "Framing",
    "IndexFormat",
    "IndexBackend",
    "IndexRef",
)
FRAME_KIND_PREFIX = "KIND_"
LOUD_WILDCARD_TOKENS = ("Err", "panic", "unreachable", "todo", "unimplemented", "bail")

ALL_CHECKS = (
    "struct-exhaustive",
    "determinism",
    "flush-ack",
    "enum-wildcard",
    "metrics-registry",
    "unsafe",
    "msrv",
    "line-length",
    "pub-doc",
    "cli-docs",
)
