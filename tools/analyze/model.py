"""Source-file model: lexed tokens plus the comment-derived structure
the checks share — suppression annotations, ``#[cfg(test)]`` regions,
and comment-block adjacency queries."""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from .lexer import Comment, Tok, lex

ANNOTATION_RE = re.compile(
    r"dart-analyze:\s*allow\(\s*([a-z0-9_-]+)\s*\)\s*:\s*(.*)"
)


@dataclass(frozen=True)
class Finding:
    """One reported violation."""

    path: str  # repo-relative path
    line: int
    check: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.check}] {self.message}"


@dataclass
class Annotation:
    """One ``// dart-analyze: allow(check): reason`` comment."""

    check: str
    reason: str
    line: int  # line the annotation comment ends on
    covers: int  # code line it suppresses
    used: bool = False


@dataclass
class SourceFile:
    """One lexed ``.rs`` file plus derived structure."""

    path: str  # repo-relative, '/'-separated
    text: str
    tokens: list[Tok] = field(default_factory=list)
    comments: list[Comment] = field(default_factory=list)
    lines: list[str] = field(default_factory=list)
    annotations: list[Annotation] = field(default_factory=list)
    test_ranges: list[tuple[int, int]] = field(default_factory=list)
    _comment_lines: set[int] = field(default_factory=set)
    _code_lines: set[int] = field(default_factory=set)

    @classmethod
    def parse(cls, path: str, text: str) -> "SourceFile":
        toks, comments = lex(text)
        sf = cls(path=path, text=text, tokens=toks, comments=comments, lines=text.split("\n"))
        for c in comments:
            sf._comment_lines.update(range(c.line, c.end_line + 1))
        for t in toks:
            sf._code_lines.add(t.line)
        sf._collect_annotations()
        sf._collect_test_ranges()
        return sf

    # -- annotations ---------------------------------------------------

    def _collect_annotations(self) -> None:
        for c in self.comments:
            m = ANNOTATION_RE.search(c.text)
            if not m:
                continue
            check, reason = m.group(1), m.group(2).strip().rstrip("*/").strip()
            covers = c.end_line if c.end_line in self._code_lines else self._next_code_line(
                c.end_line + 1
            )
            self.annotations.append(
                Annotation(check=check, reason=reason, line=c.end_line, covers=covers)
            )

    def _next_code_line(self, start: int) -> int:
        """First line >= start holding a code token, skipping blank,
        comment-only, and attribute lines (so an annotation above a
        documented/attributed item covers the item)."""
        ln = start
        last = len(self.lines)
        while ln <= last:
            if ln in self._code_lines:
                stripped = self.lines[ln - 1].lstrip()
                if stripped.startswith(("#[", "#![")):
                    ln += 1
                    continue
                return ln
            ln += 1
        return -1

    def allowed(self, check: str, line: int) -> bool:
        """True (and marks the annotation used) if ``check`` is
        suppressed at ``line`` by an adjacent annotation."""
        for a in self.annotations:
            if a.check == check and a.covers == line:
                a.used = True
                return True
        return False

    # -- test regions --------------------------------------------------

    def _collect_test_ranges(self) -> None:
        """Record line ranges of ``#[cfg(test)] mod ... { }`` blocks and
        ``#[test]``/``#[bench]`` functions, where production-byte checks
        do not apply."""
        toks = self.tokens
        i = 0
        while i < len(toks):
            if (
                toks[i].text == "#"
                and i + 1 < len(toks)
                and toks[i + 1].text == "["
            ):
                close = self._match(i + 1, "[", "]")
                attr = " ".join(t.text for t in toks[i + 2 : close])
                if attr.startswith(("cfg ( test", "test", "bench")):
                    # find the block the attribute governs
                    j = close + 1
                    while j < len(toks) and toks[j].text != "{":
                        if toks[j].text == ";":  # e.g. `#[cfg(test)] mod t;`
                            break
                        j += 1
                    if j < len(toks) and toks[j].text == "{":
                        end = self._match(j, "{", "}")
                        self.test_ranges.append((toks[i].line, toks[end].line))
                        i = close + 1
                        continue
                i = close + 1
                continue
            i += 1

    def in_test(self, line: int) -> bool:
        return any(lo <= line <= hi for lo, hi in self.test_ranges)

    # -- adjacency helpers ---------------------------------------------

    def _match(self, i_open: int, op: str, cl: str) -> int:
        """Index of the token closing the bracket opened at ``i_open``
        (or the last token index if unbalanced)."""
        depth = 0
        for j in range(i_open, len(self.tokens)):
            t = self.tokens[j].text
            if t == op:
                depth += 1
            elif t == cl:
                depth -= 1
                if depth == 0:
                    return j
        return len(self.tokens) - 1

    def comment_block_above(self, line: int) -> list[Comment]:
        """The contiguous run of comment-only lines directly above
        ``line`` (attribute-only lines are transparent), nearest last."""
        out: list[Comment] = []
        ln = line - 1
        while ln >= 1:
            if ln in self._comment_lines and ln not in self._code_lines:
                for c in self.comments:
                    if c.end_line == ln:
                        out.append(c)
                        ln = c.line - 1
                        break
                else:
                    ln -= 1
                continue
            stripped = self.lines[ln - 1].lstrip() if ln <= len(self.lines) else ""
            if stripped.startswith(("#[", "#![")) or stripped == "":
                ln -= 1
                continue
            break
        return out

    def comments_on_line(self, line: int) -> list[Comment]:
        return [c for c in self.comments if c.line <= line <= c.end_line]

    def has_adjacent(self, line: int, needle: str) -> bool:
        """True if ``needle`` appears in a comment on ``line`` or in the
        comment block directly above it."""
        for c in self.comments_on_line(line) + self.comment_block_above(line):
            if needle in c.text:
                return True
        return False
