"""dart-analyze: a toolchain-free static-analysis pass over the Rust tree.

No container this repo grows in has ever shipped a Rust toolchain
(ROADMAP P0), so every compile/correctness gate that *can* run without
`cargo` must. This package mechanizes the manual "line-by-line compile
review" that previous PRs relied on. Two tiers share one small Rust
lexer (comments, strings, and doc-comments are stripped before any
check looks at code, so a `HashMap` in prose never trips the
determinism check): *lexical* checks read one file's tokens, and
*semantic* checks run on an item-level symbol table (``items.py``) and
an intra-crate call graph (``callgraph.py``) built over the whole tree.

Run it from the repository root::

    python3 -m tools.analyze            # whole tree, exit 0 = clean
    make analyze                        # same thing
    make analyze-fast                   # findings scoped to git-changed files

Checks (each name is also its annotation key):

- ``struct-exhaustive`` — every literal construction of an analyzed
  struct (``Metrics``, ``SimCounts``) names exactly the declared fields
  or uses functional-update ``..`` syntax. Kills the E0063 class that
  shipped in PR 5 when ``SimCounts`` grew fields.
- ``determinism``      — call-graph byte-purity taint:
  ``HashMap``/``HashSet`` iteration, ``Instant``/``SystemTime``,
  unseeded randomness, and host gauges (``simd_width``,
  ``detect_wide``) are findings iff reachable from a byte-emitting
  sink (``config.TAINT_SINKS``); hazard-typed *fields* propagate too,
  so iterating ``self.sessions`` is caught without ``HashMap``
  appearing at the use site. The finding carries the witness call
  path from the sink.
- ``flush-ack``        — the epoch-barrier protocol: an ack-bearing
  message send needs its channel created in the sending fn and a
  reachable ack-receive; sent-but-unhandled and dead variants are
  findings.
- ``enum-wildcard``    — no silent ``_`` arms in matches on
  byte-affecting enums; ``KIND_*`` frame-kind matches may keep a
  wildcard only if it fails loudly.
- ``metrics-registry`` — every ``Metrics`` counter field appears in
  ``invariant_counters()`` or carries the non-invariant annotation.
- ``unsafe``           — every ``unsafe`` block/fn/impl carries an
  adjacent ``SAFETY:`` comment (or a ``# Safety`` doc section), and
  ``#[target_feature]`` fns are reached only through runtime-detection
  guards.
- ``msrv``             — denylist of std APIs stabilized after the
  declared ``rust-version = "1.74"``.
- ``line-length``      — the rustfmt 100-column limit, enforceable
  without rustfmt.
- ``pub-doc``          — public items need doc comments (the
  ``missing_docs`` gate, toolchain-free).
- ``cli-docs``         — every ``--flag`` string in ``cli.rs`` appears
  in README.md or SERVING.md.

Annotation grammar (suppresses one check at one site, reason required)::

    // dart-analyze: allow(<check>): <reason>

placed either trailing on the offending line or in the comment block
directly above it. An annotation with an unknown check name or an empty
reason is itself a finding — there is no silent allowlisting.
"""

__all__ = ["main"]

from .runner import main
