"""A small Rust lexer: just enough to separate code from non-code.

The checks in this package are token-level, so the lexer's one job is
to classify every byte of a ``.rs`` file as *code token* or *comment*
correctly — string literals (including raw/byte strings), char literals
vs. lifetimes, nested block comments, and doc comments are the cases a
naive regex pass gets wrong, and each of those wrong cases would either
hide a real violation or fabricate one.

The output is deliberately lossy in the other direction: numeric
suffixes, operator composition beyond a small multi-char set, and
keyword-vs-identifier distinctions are left to the checks.
"""

from __future__ import annotations

from dataclasses import dataclass

# Multi-char operators the checks care about, longest first. `..=` must
# precede `..` and `..` must exist so rest-patterns (`..Default::default()`,
# `Struct { .. }`) surface as one token.
_PUNCT2 = ("..=", "::", "->", "=>", "..", "&&", "||", "<<", ">>")

_IDENT_START = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ_")
_IDENT_CONT = _IDENT_START | set("0123456789")


@dataclass(frozen=True)
class Tok:
    """One code token."""

    kind: str  # "ident" | "num" | "str" | "char" | "lifetime" | "punct"
    text: str
    line: int  # 1-based line of the token's first character
    start: int = -1  # byte offset of the first character
    end: int = -1  # byte offset one past the last character

    def span(self) -> tuple[int, int]:
        """The token's ``[start, end)`` byte span; ``src[start:end] ==
        text`` is the round-trip property the tests hold."""
        return (self.start, self.end)


@dataclass(frozen=True)
class Comment:
    """One comment, with enough position info to attach it to code."""

    text: str  # raw text including the `//`/`/*` introducer
    line: int  # 1-based first line
    end_line: int  # 1-based last line (== line for line comments)
    doc: bool  # `///`, `//!`, `/**`, `/*!`
    start: int = -1  # byte offset of the first character
    end: int = -1  # byte offset one past the last character

    def span(self) -> tuple[int, int]:
        """The comment's ``[start, end)`` byte span."""
        return (self.start, self.end)


def lex(src: str):
    """Lex ``src`` into ``(tokens, comments)`` lists."""
    toks: list[Tok] = []
    comments: list[Comment] = []
    i, n, line = 0, len(src), 1

    def bump_lines(text: str) -> int:
        return text.count("\n")

    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        # -- comments ------------------------------------------------
        if c == "/" and i + 1 < n:
            nxt = src[i + 1]
            if nxt == "/":
                j = src.find("\n", i)
                if j == -1:
                    j = n
                text = src[i:j]
                comments.append(
                    Comment(
                        text,
                        line,
                        line,
                        doc=text.startswith(("///", "//!")),
                        start=i,
                        end=j,
                    )
                )
                i = j
                continue
            if nxt == "*":
                # nested block comments are legal Rust
                depth, j = 1, i + 2
                while j < n and depth:
                    if src.startswith("/*", j):
                        depth += 1
                        j += 2
                    elif src.startswith("*/", j):
                        depth -= 1
                        j += 2
                    else:
                        j += 1
                text = src[i:j]
                comments.append(
                    Comment(
                        text,
                        line,
                        line + bump_lines(text),
                        doc=text.startswith(("/**", "/*!")) and not text.startswith("/**/"),
                        start=i,
                        end=j,
                    )
                )
                line += bump_lines(text)
                i = j
                continue
        # -- string-ish literals --------------------------------------
        # raw / byte-string prefixes: r"", r#""#, b"", br"", br#""#
        if c in "rb" and _string_prefix(src, i):
            j, text = _string_prefix(src, i)
            toks.append(Tok("str", text, line, start=i, end=j))
            line += bump_lines(text)
            i = j
            continue
        if c == '"':
            j = _scan_quoted(src, i + 1)
            text = src[i:j]
            toks.append(Tok("str", text, line, start=i, end=j))
            line += bump_lines(text)
            i = j
            continue
        if c == "'":
            # char literal or lifetime
            if i + 1 < n and src[i + 1] == "\\":
                j = _scan_quoted(src, i + 2, quote="'")
                toks.append(Tok("char", src[i:j], line, start=i, end=j))
                i = j
                continue
            if i + 2 < n and src[i + 1] in _IDENT_START:
                # 'a' is a char; 'a / 'static (no closing quote) is a
                # lifetime. Scan the identifier and peek.
                j = i + 1
                while j < n and src[j] in _IDENT_CONT:
                    j += 1
                if j < n and src[j] == "'":
                    toks.append(Tok("char", src[i : j + 1], line, start=i, end=j + 1))
                    i = j + 1
                else:
                    toks.append(Tok("lifetime", src[i:j], line, start=i, end=j))
                    i = j
                continue
            if i + 2 < n and src[i + 2] == "'":
                toks.append(Tok("char", src[i : i + 3], line, start=i, end=i + 3))
                i = i + 3
                continue
            toks.append(Tok("punct", "'", line, start=i, end=i + 1))
            i += 1
            continue
        # -- identifiers / numbers ------------------------------------
        if c in _IDENT_START:
            j = i + 1
            while j < n and src[j] in _IDENT_CONT:
                j += 1
            toks.append(Tok("ident", src[i:j], line, start=i, end=j))
            i = j
            continue
        if c.isdigit():
            j = i + 1
            # good enough for 1_000, 0x5eed, 1e-3, suffixes; `..` after a
            # number must not be swallowed by a float scan
            while j < n and (src[j] in _IDENT_CONT or src[j] == "."):
                if src[j] == "." and src.startswith("..", j):
                    break
                j += 1
            toks.append(Tok("num", src[i:j], line, start=i, end=j))
            i = j
            continue
        # -- punctuation ----------------------------------------------
        for p in _PUNCT2:
            if src.startswith(p, i):
                toks.append(Tok("punct", p, line, start=i, end=i + len(p)))
                i += len(p)
                break
        else:
            toks.append(Tok("punct", c, line, start=i, end=i + 1))
            i += 1
    return toks, comments


def _scan_quoted(src: str, i: int, quote: str = '"') -> int:
    """Scan past a (non-raw) quoted literal body starting at ``i``;
    returns the index just past the closing quote."""
    n = len(src)
    while i < n:
        c = src[i]
        if c == "\\":
            i += 2
            continue
        if c == quote:
            return i + 1
        i += 1
    return n


def _string_prefix(src: str, i: int):
    """If ``src[i:]`` starts a raw/byte string, return ``(end, text)``;
    else None. Handles b"", r"", br"", rb"" and any number of #."""
    j = i
    n = len(src)
    seen = set()
    while j < n and src[j] in "rb" and src[j] not in seen:
        seen.add(src[j])
        j += 1
    raw = "r" in seen
    hashes = 0
    if raw:
        while j < n and src[j] == "#":
            hashes += 1
            j += 1
    if j >= n or src[j] != '"':
        return None
    if not raw:
        end = _scan_quoted(src, j + 1)
        return end, src[i:end]
    closer = '"' + "#" * hashes
    k = src.find(closer, j + 1)
    end = n if k == -1 else k + len(closer)
    return end, src[i:end]
