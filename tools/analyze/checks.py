"""The checks. Each takes the parsed tree and yields findings.

Every check name doubles as its annotation key — see the package doc
for the ``// dart-analyze: allow(<check>): <reason>`` grammar. A check
asks :meth:`SourceFile.allowed` *only* at a genuine violation site, so
the runner can flag never-consulted annotations as stale.
"""

from __future__ import annotations

import re

from . import config
from .items import RUST_KEYWORDS
from .model import Finding, SourceFile

FLAG_RE = re.compile(r"--[a-z][a-z0-9-]*")

_ITEM_KEYWORDS = {
    "fn",
    "struct",
    "enum",
    "trait",
    "const",
    "static",
    "type",
    "mod",
    "union",
}

# A `Name {` where the previous token is one of these is a declaration,
# type position, or body brace — not a struct literal.
_NOT_A_LITERAL_BEFORE = {"struct", "enum", "union", "trait", "impl", "for", "mod", "dyn", "->"}


# ---------------------------------------------------------------------
# shared parsing helpers


def _angle_delta(text: str) -> int:
    """Angle-bracket depth contribution of one token (`<<`/`>>` are
    single tokens after lexing)."""
    return {"<": 1, "<<": 2, ">": -1, ">>": -2}.get(text, 0)


def _skip_attr(sf: SourceFile, i: int) -> int:
    """If tokens[i] starts an attribute (`#[..]` / `#![..]`), return the
    index just past it; else return i."""
    toks = sf.tokens
    j = i
    if j < len(toks) and toks[j].text == "#":
        j += 1
        if j < len(toks) and toks[j].text == "!":
            j += 1
        if j < len(toks) and toks[j].text == "[":
            return sf._match(j, "[", "]") + 1
    return i


def parse_struct_decls(files: dict[str, SourceFile]):
    """All `struct Name { fields }` declarations in the tree:
    name -> list of (path, line, [(field, first_type_token)])."""
    decls: dict[str, list] = {}
    for sf in files.values():
        toks = sf.tokens
        for i, t in enumerate(toks):
            if t.text != "struct" or t.kind != "ident":
                continue
            if i + 1 >= len(toks) or toks[i + 1].kind != "ident":
                continue
            name = toks[i + 1].text
            j = i + 2
            # skip generics on the declaration
            angle = 0
            while j < len(toks):
                angle += _angle_delta(toks[j].text)
                if toks[j].text == "{" and angle == 0:
                    break
                if toks[j].text == ";" and angle == 0:
                    j = -1  # unit / tuple struct: nothing to check
                    break
                if toks[j].text == "(" and angle == 0:
                    j = -1  # tuple struct
                    break
                j += 1
            if j == -1 or j >= len(toks):
                continue
            fields = _parse_struct_fields(sf, j)
            decls.setdefault(name, []).append((sf.path, t.line, fields))
    return decls


def _parse_struct_fields(sf: SourceFile, i_open: int):
    """Fields of a struct body opened at token ``i_open``:
    [(name, first_type_token, decl_line)]."""
    toks = sf.tokens
    close = sf._match(i_open, "{", "}")
    fields = []
    j = i_open + 1
    while j < close:
        j = _skip_attr(sf, j)
        if j >= close:
            break
        if toks[j].text == "pub":
            j += 1
            if j < close and toks[j].text == "(":
                j = sf._match(j, "(", ")") + 1
        if (
            j + 1 < close
            and toks[j].kind == "ident"
            and toks[j + 1].text == ":"
        ):
            name_tok = toks[j]
            # first identifier of the type, for the timing-type exemption
            k = j + 2
            type_tok = toks[k].text if k < close else ""
            fields.append((name_tok.text, type_tok, name_tok.line))
        # advance to the `,` that ends this field (angle-aware)
        depth = angle = 0
        while j < close:
            txt = toks[j].text
            if txt in "([{":
                depth += 1
            elif txt in ")]}":
                depth -= 1
            angle += _angle_delta(txt) if depth == 0 else 0
            if txt == "," and depth == 0 and angle <= 0:
                j += 1
                break
            j += 1
    return fields


def _literal_sites(sf: SourceFile, names):
    """Token indices of `Name {` struct-literal/pattern sites in ``sf``."""
    toks = sf.tokens
    for i, t in enumerate(toks):
        if t.kind != "ident" or t.text not in names:
            continue
        if i + 1 >= len(toks) or toks[i + 1].text != "{":
            continue
        prev = toks[i - 1].text if i > 0 else ""
        if prev in _NOT_A_LITERAL_BEFORE:
            continue
        yield i


def _parse_literal_body(sf: SourceFile, i_open: int):
    """Field names and rest-ness of the literal body opened at
    ``i_open``: (set_of_names, has_rest)."""
    toks = sf.tokens
    close = sf._match(i_open, "{", "}")
    names: set[str] = set()
    has_rest = False
    j = i_open + 1
    while j < close:
        if toks[j].text == "..":
            has_rest = True
            break  # functional update / rest pattern ends the list
        if toks[j].kind == "ident":
            names.add(toks[j].text)
        # skip this entry's value up to the next top-level `,`
        depth = 0
        while j < close:
            txt = toks[j].text
            if txt in "([{":
                depth += 1
            elif txt in ")]}":
                depth -= 1
            if txt == "," and depth == 0:
                j += 1
                break
            j += 1
    return names, has_rest


# ---------------------------------------------------------------------
# checks


def check_struct_exhaustive(files, tree):
    decls = parse_struct_decls(files)
    out = []
    for name in config.EXHAUSTIVE_STRUCTS:
        for d in decls.get(name, []):
            _, _, fields = d
            declared = {f[0] for f in fields}
            for sf in files.values():
                for i in _literal_sites(sf, {name}):
                    line = sf.tokens[i].line
                    used, has_rest = _parse_literal_body(sf, i + 1)
                    unknown = sorted(used - declared)
                    missing = sorted(declared - used)
                    msgs = []
                    if unknown:
                        msgs.append(f"unknown field(s) {', '.join(unknown)}")
                    if missing and not has_rest:
                        msgs.append(
                            f"missing field(s) {', '.join(missing)} and no `..` base"
                        )
                    if msgs and not sf.allowed("struct-exhaustive", line):
                        out.append(
                            Finding(
                                sf.path,
                                line,
                                "struct-exhaustive",
                                f"`{name}` literal is not exhaustive: "
                                + "; ".join(msgs)
                                + f" (declared at {d[0]}:{d[1]})",
                            )
                        )
    return out


def _hazard_category(idents: tuple) -> dict:
    return {i: cat for cat, ids in config.DETERMINISM_HAZARDS.items() for i in ids}


def _hazard_fields(graph):
    """Struct fields whose type mentions a hazard identifier:
    field name -> (category, declaring path, decl line)."""
    cat_of = _hazard_category(config.DETERMINISM_HAZARDS)
    out = {}
    for fi in graph.items.values():
        for st in fi.structs:
            for fname, type_idents, fline in st.fields:
                for ti in type_idents:
                    if ti in cat_of:
                        out.setdefault(fname, (cat_of[ti], fi.path, fline))
                        break
    return out


def check_determinism(files, tree):
    """Byte-purity taint: a nondeterminism hazard is a finding iff the
    fn using it is reachable from a byte-emitting sink (config
    TAINT_SINKS) in the call graph. The hazard may be a direct
    identifier (`Instant`, `HashMap::new`) or a *use of a field* whose
    declared type is a hazard (iterating `self.sessions` never names
    `HashMap` at the use site). One finding per (file, category); the
    annotation is honored at the hazard line, the enclosing fn, or the
    hazard-typed field's declaration."""
    graph = tree.callgraph()
    cat_of = _hazard_category(config.DETERMINISM_HAZARDS)
    fields = _hazard_fields(graph)
    sink_keys = [
        fn.key for path, name in config.TAINT_SINKS for fn in graph.find(path, name)
    ]
    parents = graph.reachable(sink_keys)
    sites = []  # (path, tok start, line, category, label, fn item, field decl)
    for key in parents:
        fn = graph.fns[key]
        sf = files[fn.path]
        fi = graph.items[fn.path]
        if sf.in_test(fn.line):
            continue
        for lo, hi in fn.own_ranges():
            for k in range(lo, hi):
                t = sf.tokens[k]
                if t.kind != "ident" or fi.in_use_item(k) or sf.in_test(t.line):
                    continue
                if t.text in cat_of:
                    sites.append((fn.path, t.start, t.line, cat_of[t.text], f"`{t.text}`", key, None))
                elif t.text in fields:
                    cat, dpath, dline = fields[t.text]
                    label = f"field `{t.text}` ({cat_of_field(cat)} declared at {dpath}:{dline})"
                    sites.append((fn.path, t.start, t.line, cat, label, key, (dpath, dline)))
    sites.sort(key=lambda s: (s[0], s[1]))
    out = []
    seen = set()
    for path, _, line, category, label, key, decl in sites:
        if (path, category) in seen:
            continue
        seen.add((path, category))
        sf = files[path]
        fn = graph.fns[key]
        if sf.allowed("determinism", line) or sf.allowed("determinism", fn.line):
            continue
        if decl is not None and files[decl[0]].allowed("determinism", decl[1]):
            continue
        via = " -> ".join(graph.chain(parents, key))
        out.append(
            Finding(
                path,
                line,
                "determinism",
                f"{category} hazard {label} is reachable from emitted bytes "
                f"(sink path: {via}); prove iteration order / wall clock / "
                "randomness / host gauges never steer output bytes with "
                "`// dart-analyze: allow(determinism): <proof>` here, on the "
                "enclosing fn, or on the field declaration — or remove it",
            )
        )
    return out


def cat_of_field(cat: str) -> str:
    return {"hash-iteration": "hash container"}.get(cat, cat + " type")


def check_flush_ack(files, tree):
    """Epoch-barrier protocol lint. For every enum variant carrying an
    `ack` field (the PoolMsg::Flush/Close shape): each construction
    site must create the ack channel in the same fn and have an
    ack-receive reachable from that fn; and every variant of the enum
    must be both constructed somewhere and handled by some match arm —
    a sent-but-never-matched message is a silent drop, a
    declared-but-never-sent one is dead protocol."""
    graph = tree.callgraph()
    out = []
    enums = [e for fi in graph.items.values() for e in fi.enums]
    protocol = [e for e in enums if any("ack" in v.fields for v in e.variants)]
    for enum in protocol:
        vnames = {v.name for v in enum.variants}
        handled, constructed = set(), {}
        for path, fi in graph.items.items():
            sf = files[path]
            toks = sf.tokens
            pat_spans = fi.pattern_spans()
            for k, t in enumerate(toks):
                if (
                    t.text not in vnames
                    or k < 2
                    or toks[k - 1].text != "::"
                    or toks[k - 2].text != enum.name
                ):
                    continue
                if any(lo <= k < hi for lo, hi in pat_spans):
                    handled.add(t.text)
                elif not fi.in_use_item(k):
                    constructed.setdefault(t.text, []).append((path, k, t.line))
        for v in enum.variants:
            if "ack" not in v.fields:
                continue
            for path, k, line in constructed.get(v.name, []):
                sf = files[path]
                fn = graph.enclosing(path, k)
                if fn is None or sf.in_test(line):
                    continue
                probs = []
                if not _fn_mentions(sf, graph.items[path], fn, config.CHANNEL_IDENTS):
                    probs.append(
                        "no ack channel is created in the sending fn "
                        f"({'/'.join(config.CHANNEL_IDENTS)})"
                    )
                if not _recv_reachable(graph, files, fn):
                    probs.append(
                        "no ack receive "
                        f"({'/'.join(config.RECV_IDENTS)}) is reachable from the "
                        "sending fn — the barrier cannot complete"
                    )
                for prob in probs:
                    if sf.allowed("flush-ack", line) or sf.allowed("flush-ack", fn.line):
                        continue
                    out.append(
                        Finding(
                            path,
                            line,
                            "flush-ack",
                            f"`{enum.name}::{v.name}` sent here but {prob}",
                        )
                    )
        for v in enum.variants:
            decl_sf = files[enum.path]
            if v.name in constructed and v.name not in handled:
                path, _, line = constructed[v.name][0]
                if not files[path].allowed("flush-ack", line):
                    out.append(
                        Finding(
                            path,
                            line,
                            "flush-ack",
                            f"`{enum.name}::{v.name}` is sent but no match arm "
                            "anywhere handles it — the receiver drops it silently",
                        )
                    )
            elif v.name not in constructed and v.name not in handled:
                if not decl_sf.allowed("flush-ack", v.line):
                    out.append(
                        Finding(
                            enum.path,
                            v.line,
                            "flush-ack",
                            f"`{enum.name}::{v.name}` is declared but never sent "
                            "nor handled — dead protocol message",
                        )
                    )
    return out


def _fn_mentions(sf, fi, fn, idents) -> bool:
    return any(
        sf.tokens[k].kind == "ident"
        and sf.tokens[k].text in idents
        and not fi.in_use_item(k)
        for lo, hi in fn.own_ranges()
        for k in range(lo, hi)
    )


def _recv_reachable(graph, files, fn) -> bool:
    for key in graph.reachable([fn.key]):
        callee = graph.fns[key]
        if _fn_mentions(files[callee.path], graph.items[callee.path], callee, config.RECV_IDENTS):
            return True
    return False


def check_enum_wildcard(files, tree):
    """Silent-fallthrough audit: a `match` whose arms name a configured
    byte-affecting enum must not end in an unguarded `_`/bare-binding
    arm; a match over `KIND_*` frame constants may keep its wildcard
    only if the arm is loud (error or panic family)."""
    graph = tree.callgraph()
    out = []
    for path, fi in graph.items.items():
        sf = files[path]
        toks = sf.tokens
        for m in fi.matches:
            enums, kind_consts = set(), False
            for arm in m.arms:
                for k in range(*arm.pat):
                    t = toks[k]
                    if (
                        t.kind == "ident"
                        and t.text in config.WILDCARD_ENUMS
                        and k + 1 < arm.pat[1]
                        and toks[k + 1].text == "::"
                    ):
                        enums.add(t.text)
                    if t.kind == "ident" and t.text.startswith(config.FRAME_KIND_PREFIX):
                        kind_consts = True
            if not enums and not kind_consts:
                continue
            for arm in m.arms:
                if arm.has_guard or not _is_wildcard_arm(toks, arm):
                    continue
                loud = any(
                    toks[k].kind == "ident" and toks[k].text in config.LOUD_WILDCARD_TOKENS
                    for k in range(*arm.body)
                )
                if enums:
                    what = "/".join(sorted(enums))
                    msg = (
                        f"wildcard arm in a match on byte-affecting enum `{what}`: "
                        "a new variant would fall through silently; name every "
                        "variant, or annotate with the reason the fallthrough is "
                        "byte-safe"
                    )
                elif not loud:
                    msg = (
                        f"silent wildcard arm in a match over `{config.FRAME_KIND_PREFIX}*` "
                        "frame kinds: unknown kinds must fail loudly "
                        f"({'/'.join(config.LOUD_WILDCARD_TOKENS[:3])}...), not be absorbed"
                    )
                else:
                    continue
                if sf.allowed("enum-wildcard", arm.line) or sf.allowed("enum-wildcard", m.line):
                    continue
                if sf.in_test(arm.line):
                    continue
                out.append(Finding(path, arm.line, "enum-wildcard", msg))
    return out


def _is_wildcard_arm(toks, arm) -> bool:
    lo, hi = arm.pat
    pat = [toks[k] for k in range(lo, hi)]
    if len(pat) != 1:
        return False
    t = pat[0]
    if t.text == "_":
        return True
    # a bare lowercase binding (`other => ...`) catches everything too;
    # lowercase excludes unit variants like `None` by Rust convention
    return t.kind == "ident" and t.text[0].islower() and t.text not in RUST_KEYWORDS


def check_metrics_registry(files, tree):
    out = []
    for sf in files.values():
        toks = sf.tokens
        has_registry = any(
            t.text == "invariant_counters" and i > 0 and toks[i - 1].text == "fn"
            for i, t in enumerate(toks)
        )
        decl_idx = next(
            (
                i
                for i, t in enumerate(toks)
                if t.text == "struct" and i + 1 < len(toks) and toks[i + 1].text == "Metrics"
            ),
            None,
        )
        if not has_registry or decl_idx is None:
            continue
        # fields
        j = decl_idx + 2
        while j < len(toks) and toks[j].text != "{":
            j += 1
        fields = _parse_struct_fields(sf, j)
        # idents mentioned as `self.<x>` inside invariant_counters body
        registered: set[str] = set()
        for i, t in enumerate(toks):
            if t.text == "invariant_counters" and toks[i - 1].text == "fn":
                k = i
                while k < len(toks) and toks[k].text != "{":
                    k += 1
                body_end = sf._match(k, "{", "}")
                for m in range(k, body_end):
                    if (
                        toks[m].text == "self"
                        and m + 2 < body_end
                        and toks[m + 1].text == "."
                        and toks[m + 2].kind == "ident"
                    ):
                        registered.add(toks[m + 2].text)
        for name, type_tok, line in fields:
            if type_tok in config.METRICS_TIMING_TYPES:
                continue
            if name in registered:
                continue
            if sf.allowed("metrics-registry", line):
                continue
            out.append(
                Finding(
                    sf.path,
                    line,
                    "metrics-registry",
                    f"`Metrics::{name}` is not in invariant_counters() and carries "
                    "no `// dart-analyze: allow(metrics-registry): <why it is not "
                    "a workload invariant>` annotation (invariant 4)",
                )
            )
    return out


def check_unsafe(files, tree):
    out = []
    tf_fns: list[tuple[str, str]] = []  # (path, fn name)
    for sf in files.values():
        toks = sf.tokens
        for i, t in enumerate(toks):
            # record #[target_feature] fn names
            if (
                t.text == "target_feature"
                and i >= 2
                and toks[i - 1].text == "["
                and toks[i - 2].text == "#"
            ):
                k = sf._match(i - 1, "[", "]") + 1
                while k < len(toks) and toks[k].text != "fn":
                    k = max(k + 1, _skip_attr(sf, k))
                if k + 1 < len(toks) and toks[k + 1].kind == "ident":
                    tf_fns.append((sf.path, toks[k + 1].text))
                if "is_x86_feature_detected" not in sf.text:
                    out.append(
                        Finding(
                            sf.path,
                            t.line,
                            "unsafe",
                            "#[target_feature] fn in a file with no "
                            "is_x86_feature_detected! runtime guard",
                        )
                    )
            if t.kind != "ident" or t.text != "unsafe":
                continue
            nxt = toks[i + 1].text if i + 1 < len(toks) else ""
            if nxt == "fn":
                if i + 2 < len(toks) and toks[i + 2].text == "(":
                    continue  # `unsafe fn(..)` pointer type, not a decl
                ok = sf.has_adjacent(t.line, "SAFETY") or sf.has_adjacent(t.line, "# Safety")
                what = "unsafe fn"
            elif nxt == "{":
                ok = sf.has_adjacent(t.line, "SAFETY")
                what = "unsafe block"
            elif nxt in ("impl", "extern", "trait"):
                ok = sf.has_adjacent(t.line, "SAFETY") or sf.has_adjacent(t.line, "# Safety")
                what = f"unsafe {nxt}"
            else:
                continue
            if not ok and not sf.allowed("unsafe", t.line):
                out.append(
                    Finding(
                        sf.path,
                        t.line,
                        "unsafe",
                        f"{what} without an adjacent `// SAFETY:` comment "
                        "(or `# Safety` doc section) stating the discharged "
                        "precondition",
                    )
                )
    # every call of a #[target_feature] fn needs its own SAFETY comment:
    # the runtime-detection guard is the precondition being discharged.
    names = {n for _, n in tf_fns}
    for sf in files.values():
        toks = sf.tokens
        for i, t in enumerate(toks):
            if t.kind != "ident" or t.text not in names:
                continue
            if i + 1 >= len(toks) or toks[i + 1].text != "(":
                continue
            if i > 0 and toks[i - 1].text == "fn":
                continue  # the definition itself
            if not sf.has_adjacent(t.line, "SAFETY") and not sf.allowed("unsafe", t.line):
                out.append(
                    Finding(
                        sf.path,
                        t.line,
                        "unsafe",
                        f"call of #[target_feature] fn `{t.text}` without an "
                        "adjacent `// SAFETY:` comment naming the runtime "
                        "detection that guards it",
                    )
                )
    return out


def check_msrv(files, tree):
    out = []
    for sf in files.values():
        for t in sf.tokens:
            if t.kind == "ident" and t.text in config.MSRV_DENYLIST:
                if sf.allowed("msrv", t.line):
                    continue
                out.append(
                    Finding(
                        sf.path,
                        t.line,
                        "msrv",
                        f"`{t.text}` needs Rust {config.MSRV_DENYLIST[t.text]} but "
                        f"rust-version pins {config.MSRV}",
                    )
                )
    return out


def check_line_length(files, tree):
    out = []
    for sf in files.values():
        for ln, text in enumerate(sf.lines, start=1):
            if len(text) > config.MAX_WIDTH and not sf.allowed("line-length", ln):
                out.append(
                    Finding(
                        sf.path,
                        ln,
                        "line-length",
                        f"line is {len(text)} columns (rustfmt max_width is "
                        f"{config.MAX_WIDTH})",
                    )
                )
    return out


def check_pub_doc(files, tree):
    out = []
    for sf in files.values():
        if not sf.path.startswith(tuple(d + "/" for d in config.PUB_DOC_DIRS)):
            continue
        toks = sf.tokens
        for i, t in enumerate(toks):
            if t.kind != "ident" or t.text != "pub" or sf.in_test(t.line):
                continue
            nxt = toks[i + 1] if i + 1 < len(toks) else None
            if nxt is None or nxt.text in ("(", "use"):
                continue  # restricted visibility / re-export
            j = i + 1
            while j < len(toks) and toks[j].text in ("unsafe", "async", "extern") or (
                j < len(toks) and toks[j].kind == "str"
            ):
                j += 1
            if j >= len(toks):
                continue
            kw = toks[j].text
            is_field = (
                toks[j].kind == "ident"
                and kw not in _ITEM_KEYWORDS
                and j + 1 < len(toks)
                and toks[j + 1].text == ":"
            )
            if kw not in _ITEM_KEYWORDS and not is_field:
                continue
            if _has_doc(sf, t.line):
                continue
            if kw == "mod" and _mod_file_has_inner_doc(files, sf, toks, j):
                continue
            if sf.allowed("pub-doc", t.line):
                continue
            what = "field" if is_field else f"`pub {kw}`"
            name = toks[j + 1].text if j + 1 < len(toks) and not is_field else kw
            if is_field:
                name = kw
            out.append(
                Finding(
                    sf.path,
                    t.line,
                    "pub-doc",
                    f"public {what} `{name}` has no doc comment (missing_docs "
                    "is a CI docs-job error; document it here instead of "
                    "waiting for a toolchain)",
                )
            )
    return out


def _has_doc(sf: SourceFile, line: int) -> bool:
    # Only *outer* docs (`///`, `/**`) document the item below; inner
    # (`//!`) docs belong to the enclosing module and must not satisfy
    # the first item in a file.
    for c in sf.comment_block_above(line):
        if c.doc and c.text.startswith(("///", "/**")):
            return True
    # #[doc = ...] / #[doc(hidden)] attributes count
    ln = line - 1
    while ln >= 1:
        stripped = sf.lines[ln - 1].lstrip()
        if stripped.startswith("#[doc"):
            return True
        if stripped.startswith(("#[", "#![")) or stripped == "":
            ln -= 1
            continue
        break
    return False


def _mod_file_has_inner_doc(files, sf: SourceFile, toks, j: int) -> bool:
    """`pub mod name;` is documented if name.rs / name/mod.rs opens with
    inner docs (`//!`)."""
    if j + 1 >= len(toks) or toks[j + 1].kind != "ident":
        return False
    if j + 2 >= len(toks) or toks[j + 2].text != ";":
        return False
    name = toks[j + 1].text
    base = sf.path.rsplit("/", 1)[0]
    for cand in (f"{base}/{name}.rs", f"{base}/{name}/mod.rs"):
        target = files.get(cand)
        if target and any(c.doc and c.text.startswith("//!") for c in target.comments):
            return True
    return False


def check_cli_docs(files, tree):
    out = []
    cli = files.get(config.CLI_FILE)
    if cli is None:
        return out
    docs_text = ""
    for doc in config.CLI_DOC_FILES:
        docs_text += tree.read_doc(doc)
    seen: set[str] = set()
    for t in cli.tokens:
        if t.kind != "str":
            continue
        for flag in FLAG_RE.findall(t.text):
            if flag in seen:
                continue
            seen.add(flag)
            if flag not in docs_text and not cli.allowed("cli-docs", t.line):
                out.append(
                    Finding(
                        cli.path,
                        t.line,
                        "cli-docs",
                        f"flag `{flag}` appears in cli.rs but in none of "
                        f"{', '.join(config.CLI_DOC_FILES)}",
                    )
                )
    return out


CHECKS = {
    "struct-exhaustive": check_struct_exhaustive,
    "determinism": check_determinism,
    "flush-ack": check_flush_ack,
    "enum-wildcard": check_enum_wildcard,
    "metrics-registry": check_metrics_registry,
    "unsafe": check_unsafe,
    "msrv": check_msrv,
    "line-length": check_line_length,
    "pub-doc": check_pub_doc,
    "cli-docs": check_cli_docs,
}
