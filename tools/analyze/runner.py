"""Discovery, orchestration, and reporting for ``python3 -m tools.analyze``."""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

from . import config
from .callgraph import CallGraph
from .checks import CHECKS
from .model import Finding, SourceFile


class Tree:
    """The analyzed file set rooted at one directory."""

    def __init__(self, root: Path):
        self.root = root
        self.files: dict[str, SourceFile] = {}
        self._graph: CallGraph | None = None

    def load(self) -> None:
        for scan in config.SCAN_DIRS:
            base = self.root / scan
            if not base.is_dir():
                continue
            for path in sorted(base.rglob("*.rs")):
                rel = path.relative_to(self.root).as_posix()
                if rel.startswith(tuple(d + "/" for d in config.EXCLUDE_DIRS)):
                    continue
                self.files[rel] = SourceFile.parse(
                    rel, path.read_text(encoding="utf-8", errors="replace")
                )

    def callgraph(self) -> CallGraph:
        """Item table + call graph, built once and shared by the
        semantic checks."""
        if self._graph is None:
            self._graph = CallGraph(self.files)
        return self._graph

    def read_doc(self, rel: str) -> str:
        path = self.root / rel
        return path.read_text(encoding="utf-8") if path.is_file() else ""


def validate_annotations(tree: Tree, checks_run) -> list[Finding]:
    """Annotations are themselves checked: unknown check names, empty
    reasons, and allows that matched no violation are findings — the
    allowlist cannot rot silently."""
    out = []
    for sf in tree.files.values():
        for a in sf.annotations:
            if a.check not in config.ALL_CHECKS:
                out.append(
                    Finding(
                        sf.path,
                        a.line,
                        "annotation",
                        f"allow({a.check}) names no known check "
                        f"(known: {', '.join(config.ALL_CHECKS)})",
                    )
                )
                continue
            if not a.reason:
                out.append(
                    Finding(
                        sf.path,
                        a.line,
                        "annotation",
                        f"allow({a.check}) has an empty reason; every "
                        "suppression must say why",
                    )
                )
                continue
            if a.check in checks_run and not a.used:
                out.append(
                    Finding(
                        sf.path,
                        a.line,
                        "annotation",
                        f"allow({a.check}) suppresses nothing at its site "
                        "(stale annotation — remove it, or move it to the "
                        "violation it is meant to cover)",
                    )
                )
    return out


def changed_paths(root: Path) -> set[str]:
    """Repo-relative paths touched per git: unstaged + staged diffs and
    untracked files. Empty when git is unavailable (degrades to the
    full run rather than silently analyzing nothing)."""
    out: set[str] = set()
    cmds = (
        ["git", "diff", "--name-only"],
        ["git", "diff", "--name-only", "--cached"],
        ["git", "ls-files", "--others", "--exclude-standard"],
    )
    for cmd in cmds:
        try:
            p = subprocess.run(cmd, cwd=root, capture_output=True, text=True, timeout=30)
        except (OSError, subprocess.TimeoutExpired):
            return set()
        if p.returncode != 0:
            return set()
        out.update(ln.strip() for ln in p.stdout.splitlines() if ln.strip())
    return out


def run(root: Path, checks: list[str], changed: set[str] | None = None) -> list[Finding]:
    """Run ``checks`` over the tree at ``root``. With ``changed``, the
    whole tree is still loaded (taint and call resolution stay global)
    but findings are filtered to the changed files — a hazard you just
    introduced in an untouched file's callee still names *that* file,
    so `--changed` trades recall for speed only in reporting scope."""
    tree = Tree(root)
    tree.load()
    findings: list[Finding] = []
    for name in checks:
        findings.extend(CHECKS[name](tree.files, tree))
    findings.extend(validate_annotations(tree, set(checks)))
    if changed is not None:
        findings = [f for f in findings if f.path in changed]
    findings.sort(key=lambda f: (f.path, f.line, f.check))
    return findings


def write_bench(path: Path, elapsed: float, n_files: int, n_findings: int, budget: float) -> None:
    path.write_text(
        json.dumps(
            {
                "tool": "dart-analyze",
                "wall_s": round(elapsed, 3),
                "budget_s": budget,
                "files": n_files,
                "findings": n_findings,
                "within_budget": elapsed < budget,
            },
            indent=2,
            sort_keys=True,
        )
        + "\n"
    )


def verify_fixtures(root: Path) -> int:
    """CI drift gate: every fixture dir is in the manifest and vice
    versa, and every expected finding names a file that exists."""
    fixtures = root / "tools" / "analyze" / "fixtures"
    manifest = json.loads((fixtures / "manifest.json").read_text())
    listed = {c["dir"] for c in manifest["cases"]}
    present = {d.name for d in fixtures.iterdir() if d.is_dir()}
    bad = 0
    for name in sorted(listed ^ present):
        where = "manifest only" if name in listed else "directory only"
        print(f"fixture drift: {name} ({where})", file=sys.stderr)
        bad += 1
    for case in manifest["cases"]:
        for f in case.get("findings", ()):
            if not (fixtures / case["dir"] / f["file"]).is_file():
                print(
                    f"fixture drift: {case['dir']} expects findings in "
                    f"missing file {f['file']}",
                    file=sys.stderr,
                )
                bad += 1
    if bad:
        return 1
    print(f"dart-analyze: fixture manifest is drift-free ({len(listed)} cases)", file=sys.stderr)
    return 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python3 -m tools.analyze",
        description="Toolchain-free static analysis of the Rust tree "
        "(determinism taint, protocol lints, unsafe audit, MSRV, docs parity).",
    )
    parser.add_argument(
        "--root",
        type=Path,
        default=None,
        help="tree root (default: the repository containing this package)",
    )
    parser.add_argument(
        "--check",
        action="append",
        choices=sorted(CHECKS),
        help="run only this check (repeatable; default: all)",
    )
    parser.add_argument(
        "--list-checks", action="store_true", help="list check names and exit"
    )
    parser.add_argument(
        "--changed",
        action="store_true",
        help="report only findings in git-changed files (analysis itself "
        "stays whole-tree so call resolution is unaffected)",
    )
    parser.add_argument(
        "--changed-from",
        metavar="FILE",
        default=None,
        help=argparse.SUPPRESS,  # test hook: newline-separated path list
    )
    parser.add_argument(
        "--format",
        choices=("text", "github", "sarif"),
        default="text",
        help="finding output format (default: text)",
    )
    parser.add_argument(
        "--bench",
        metavar="FILE",
        default=None,
        help="write wall-time/budget JSON to FILE and fail if the run "
        "exceeds the budget",
    )
    parser.add_argument(
        "--budget-s",
        type=float,
        default=10.0,
        help="wall-time budget for --bench (default: 10)",
    )
    parser.add_argument(
        "--verify-fixtures",
        action="store_true",
        help="check the fixture manifest against the fixtures directory and exit",
    )
    args = parser.parse_args(argv)

    if args.list_checks:
        for name in config.ALL_CHECKS:
            print(name)
        return 0

    root = args.root or Path(__file__).resolve().parents[2]

    if args.verify_fixtures:
        return verify_fixtures(root)

    changed: set[str] | None = None
    if args.changed_from is not None:
        changed = {
            ln.strip()
            for ln in Path(args.changed_from).read_text().splitlines()
            if ln.strip()
        }
    elif args.changed:
        changed = changed_paths(root) or None

    checks = args.check or list(config.ALL_CHECKS)
    t0 = time.monotonic()
    findings = run(root, checks, changed)
    elapsed = time.monotonic() - t0

    from .report import RENDERERS

    rendered = RENDERERS[args.format](findings)
    if rendered:
        print(rendered)

    scope = f" [changed: {len(changed)} path(s)]" if changed is not None else ""
    if args.bench is not None:
        n_files = len(list((root / "rust").rglob("*.rs"))) if (root / "rust").is_dir() else 0
        write_bench(Path(args.bench), elapsed, n_files, len(findings), args.budget_s)
        print(
            f"dart-analyze: {elapsed:.2f}s wall (budget {args.budget_s:.0f}s)",
            file=sys.stderr,
        )
        if elapsed >= args.budget_s:
            print("dart-analyze: over wall-time budget", file=sys.stderr)
            return 2

    if findings:
        print(
            f"dart-analyze: {len(findings)} finding(s) "
            f"[checks: {', '.join(checks)}]{scope}",
            file=sys.stderr,
        )
        return 1
    print(
        f"dart-analyze: clean [checks: {', '.join(checks)}]{scope}",
        file=sys.stderr,
    )
    return 0
