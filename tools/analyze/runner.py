"""Discovery, orchestration, and reporting for ``python3 -m tools.analyze``."""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import config
from .checks import CHECKS
from .model import Finding, SourceFile


class Tree:
    """The analyzed file set rooted at one directory."""

    def __init__(self, root: Path):
        self.root = root
        self.files: dict[str, SourceFile] = {}

    def load(self) -> None:
        for scan in config.SCAN_DIRS:
            base = self.root / scan
            if not base.is_dir():
                continue
            for path in sorted(base.rglob("*.rs")):
                rel = path.relative_to(self.root).as_posix()
                if rel.startswith(tuple(d + "/" for d in config.EXCLUDE_DIRS)):
                    continue
                self.files[rel] = SourceFile.parse(
                    rel, path.read_text(encoding="utf-8", errors="replace")
                )

    def read_doc(self, rel: str) -> str:
        path = self.root / rel
        return path.read_text(encoding="utf-8") if path.is_file() else ""


def validate_annotations(tree: Tree, checks_run) -> list[Finding]:
    """Annotations are themselves checked: unknown check names, empty
    reasons, and allows that matched no violation are findings — the
    allowlist cannot rot silently."""
    out = []
    for sf in tree.files.values():
        for a in sf.annotations:
            if a.check not in config.ALL_CHECKS:
                out.append(
                    Finding(
                        sf.path,
                        a.line,
                        "annotation",
                        f"allow({a.check}) names no known check "
                        f"(known: {', '.join(config.ALL_CHECKS)})",
                    )
                )
                continue
            if not a.reason:
                out.append(
                    Finding(
                        sf.path,
                        a.line,
                        "annotation",
                        f"allow({a.check}) has an empty reason; every "
                        "suppression must say why",
                    )
                )
                continue
            if a.check in checks_run and not a.used:
                out.append(
                    Finding(
                        sf.path,
                        a.line,
                        "annotation",
                        f"allow({a.check}) suppresses nothing at its site "
                        "(stale annotation — remove it, or move it to the "
                        "violation it is meant to cover)",
                    )
                )
    return out


def run(root: Path, checks: list[str]) -> list[Finding]:
    tree = Tree(root)
    tree.load()
    findings: list[Finding] = []
    for name in checks:
        findings.extend(CHECKS[name](tree.files, tree))
    findings.extend(validate_annotations(tree, set(checks)))
    findings.sort(key=lambda f: (f.path, f.line, f.check))
    return findings


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python3 -m tools.analyze",
        description="Toolchain-free static analysis of the Rust tree "
        "(determinism invariants, unsafe audit, MSRV, docs parity).",
    )
    parser.add_argument(
        "--root",
        type=Path,
        default=None,
        help="tree root (default: the repository containing this package)",
    )
    parser.add_argument(
        "--check",
        action="append",
        choices=sorted(CHECKS),
        help="run only this check (repeatable; default: all)",
    )
    parser.add_argument(
        "--list-checks", action="store_true", help="list check names and exit"
    )
    args = parser.parse_args(argv)

    if args.list_checks:
        for name in config.ALL_CHECKS:
            print(name)
        return 0

    root = args.root or Path(__file__).resolve().parents[2]
    checks = args.check or list(config.ALL_CHECKS)
    findings = run(root, checks)
    for f in findings:
        print(f.render())
    if findings:
        print(
            f"dart-analyze: {len(findings)} finding(s) "
            f"[checks: {', '.join(checks)}]",
            file=sys.stderr,
        )
        return 1
    print(
        f"dart-analyze: clean [checks: {', '.join(checks)}]",
        file=sys.stderr,
    )
    return 0
