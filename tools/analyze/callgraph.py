"""Intra-crate call graph over the item table (`items.py`).

Crate partitioning mirrors Cargo's: everything under ``rust/src/`` is
the one lib crate; each file under ``rust/tests``, ``rust/benches``,
and ``examples`` is its own crate that can additionally resolve into
the lib (the dependency direction Cargo gives integration tests).

Resolution is name-based with path/`use`/receiver narrowing, and it
over-approximates on purpose: a method call ``x.f()`` links to every
in-crate impl fn named ``f`` unless the receiver is ``self`` and the
caller's impl type pins it down. Extra edges can only widen
reachability — a taint check built on this graph may ask for a proof
it strictly didn't need, but it can never miss a real path from a
hazard to an emit site. Calls whose callee lives outside the tree
(std, vendored APIs) resolve to nothing and create no edge.
"""

from __future__ import annotations

from collections import deque

from .items import RUST_KEYWORDS, FileItems, FnItem, parse_file

# Path segments that scope but don't name a module we model.
_PATH_FILLER = {"crate", "self", "super", "Self"}


class CallGraph:
    """Fns, edges, and reachability queries for one analyzed tree."""

    def __init__(self, files):
        self.files = files
        self.items: dict[str, FileItems] = {p: parse_file(sf) for p, sf in files.items()}
        self.fns: dict[tuple, FnItem] = {}
        self._by_crate: dict[str, dict] = {}
        for path, fi in self.items.items():
            crate = self.crate_of(path)
            idx = self._by_crate.setdefault(
                crate, {"by_name": {}, "by_typed": {}, "by_qual": {}}
            )
            for fn in fi.fns:
                self.fns[fn.key] = fn
                idx["by_name"].setdefault(fn.name, []).append(fn.key)
                if fn.self_type:
                    idx["by_typed"].setdefault((fn.self_type, fn.name), []).append(fn.key)
                idx["by_qual"].setdefault(fn.qual + (fn.name,), []).append(fn.key)
        self.edges: dict[tuple, set] = {k: set() for k in self.fns}
        for path, fi in self.items.items():
            for fn in fi.fns:
                self._link(fn, fi)

    @staticmethod
    def crate_of(path: str) -> str:
        return "lib" if path.startswith("rust/src/") else path

    def _indices(self, path: str):
        """Resolution indices for a file: its own crate, then the lib
        crate for test/bench/example crates."""
        crate = self.crate_of(path)
        out = [self._by_crate[crate]]
        if crate != "lib" and "lib" in self._by_crate:
            out.append(self._by_crate["lib"])
        return out

    # -- edge construction ---------------------------------------------

    def _link(self, fn: FnItem, fi: FileItems) -> None:
        sf = self.files[fn.path]
        toks = sf.tokens
        for lo, hi in fn.own_ranges():
            k = lo
            while k < hi:
                t = toks[k]
                if (
                    t.kind == "ident"
                    and t.text not in RUST_KEYWORDS
                    and k + 1 < hi
                    and toks[k + 1].text == "("
                    and not fi.in_use_item(k)
                ):
                    prev = toks[k - 1].text if k > 0 else ""
                    if prev == ".":
                        self._link_method(fn, toks, k)
                    elif prev == "::":
                        self._link_path(fn, fi, toks, k)
                    else:
                        self._link_plain(fn, fi, t.text)
                k += 1

    def _add(self, fn: FnItem, keys) -> None:
        for key in keys:
            if key != fn.key:
                self.edges[fn.key].add(key)

    def _link_method(self, fn: FnItem, toks, k: int) -> None:
        name = toks[k].text
        # `self.f()` inside `impl T` pins the candidate set to T's fns
        if fn.self_type and k >= 2 and toks[k - 2].text == "self":
            for idx in self._indices(fn.path):
                keys = idx["by_typed"].get((fn.self_type, name))
                if keys:
                    self._add(fn, keys)
                    return
        for idx in self._indices(fn.path):
            for (_, n), keys in idx["by_typed"].items():
                if n == name:
                    self._add(fn, keys)

    def _link_path(self, fn: FnItem, fi: FileItems, toks, k: int) -> None:
        # collect the `a::b::name` segment chain ending at toks[k]
        segs = []
        j = k - 1
        while j >= 1 and toks[j].text == "::":
            if toks[j - 1].kind == "ident":
                segs.append(toks[j - 1].text)
                j -= 2
            elif toks[j - 1].text == ">":  # `<T as Trait>::f` — give up on the type
                break
            else:
                break
        segs.reverse()
        name = toks[k].text
        if segs and segs[0] in fi.uses:
            segs = list(fi.uses[segs[0]]) + segs[1:]
        segs = [s for s in segs if s not in _PATH_FILLER]
        for idx in self._indices(fn.path):
            if segs:
                keys = idx["by_typed"].get((segs[-1], name))
                if keys:
                    self._add(fn, keys)
                    return
                keys = idx["by_qual"].get(tuple(segs) + (name,))
                if keys:
                    self._add(fn, keys)
                    return
        # `std::mem::take`-style externals fall through to by-name,
        # which simply finds nothing in-crate.
        self._link_plain(fn, fi, name)

    def _link_plain(self, fn: FnItem, fi: FileItems, name: str) -> None:
        for idx in self._indices(fn.path):
            keys = idx["by_qual"].get(fn.qual + (name,))
            if keys:
                self._add(fn, keys)
                return
        if name in fi.uses:
            segs = [s for s in fi.uses[name] if s not in _PATH_FILLER]
            if len(segs) >= 2:
                for idx in self._indices(fn.path):
                    keys = idx["by_qual"].get(tuple(segs))
                    if keys:
                        self._add(fn, keys)
                        return
        for idx in self._indices(fn.path):
            keys = idx["by_name"].get(name)
            if keys:
                self._add(fn, keys)
                return

    # -- queries --------------------------------------------------------

    def find(self, path: str, name: str):
        """All fns named ``name`` declared in ``path``."""
        fi = self.items.get(path)
        return [fn for fn in fi.fns if fn.name == name] if fi else []

    def enclosing(self, path: str, tok_idx: int):
        """Innermost fn whose body contains token ``tok_idx``."""
        fi = self.items.get(path)
        best = None
        for fn in fi.fns if fi else []:
            lo, hi = fn.body
            if lo <= tok_idx < hi and (best is None or lo > best.body[0]):
                best = fn
        return best

    def reachable(self, start_keys):
        """BFS forward over callee edges: key -> parent key (roots map
        to None). Deterministic: queue order follows sorted keys."""
        parents: dict[tuple, tuple | None] = {}
        dq = deque()
        for key in sorted(start_keys):
            if key in self.fns and key not in parents:
                parents[key] = None
                dq.append(key)
        while dq:
            cur = dq.popleft()
            for nxt in sorted(self.edges.get(cur, ())):
                if nxt not in parents:
                    parents[nxt] = cur
                    dq.append(nxt)
        return parents

    def chain(self, parents, key) -> list[str]:
        """Call path root -> ... -> ``key`` as fn names, for messages."""
        names = []
        cur = key
        while cur is not None:
            fn = self.fns[cur]
            names.append(f"{fn.self_type}::{fn.name}" if fn.self_type else fn.name)
            cur = parents.get(cur)
        names.reverse()
        return names
