# Repository tooling namespace (stdlib-only; no third-party imports).
