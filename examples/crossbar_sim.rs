//! Single-crossbar simulator walk-through (paper §IV, Tables I/IV):
//! MAGIC op costs, the per-cell op sequence, instance totals
//! (constructive vs published), per-instance energy, and the crossbar
//! row bit-allocation of Figs. 3/6.
//!
//!     cargo run --release --example crossbar_sim

use dart_pim::eval::figures;
use dart_pim::pim::energy::EnergyModel;
use dart_pim::pim::magic::MagicOp;
use dart_pim::pim::xbar_sim::{
    affine_cell_ops, affine_instance_cost, affine_row_allocation, linear_cell_ops,
    linear_instance_cost, linear_row_allocation, traceback_bits, CostSource, B_AFFINE, B_LINEAR,
};
use dart_pim::params::READ_LEN;

fn main() {
    println!("== Table I: MAGIC NOR composite op cycles (b = 3) ==");
    for (name, op) in [
        ("AND", MagicOp::And(3)),
        ("XNOR", MagicOp::Xnor(3)),
        ("XOR", MagicOp::Xor(3)),
        ("Copy", MagicOp::Copy(3)),
        ("Add NxN", MagicOp::Add(3)),
        ("Add N+1b", MagicOp::AddBit(3)),
        ("Add const", MagicOp::AddConst(3)),
        ("Sub", MagicOp::Sub(3)),
        ("Mux", MagicOp::Mux(3)),
        ("Min", MagicOp::Min(3)),
    ] {
        println!("  {:<10} {:>4} cycles", name, op.cycles());
    }

    println!("\n== Algorithm 1: linear WF cell op sequence (b = {B_LINEAR}) ==");
    let cell = linear_cell_ops(B_LINEAR);
    println!(
        "  {} ops, {} cycles/cell (paper: 37b+19 = {})",
        cell.len(),
        MagicOp::total(&cell),
        37 * B_LINEAR + 19
    );
    let acell = affine_cell_ops(B_AFFINE);
    println!(
        "  affine cell (b = {B_AFFINE}): {} ops, {} cycles/cell (constructive)",
        acell.len(),
        MagicOp::total(&acell)
    );

    println!("\n{}", figures::table4());

    let e = EnergyModel::default();
    println!("== per-instance energy (90 fJ/switch, Table V) ==");
    println!(
        "  linear: {:.1} nJ (paper: 45.9)   affine: {:.1} nJ (paper: 229)",
        e.instance_energy(&linear_instance_cost(CostSource::PaperTable4)) * 1e9,
        e.instance_energy(&affine_instance_cost(CostSource::PaperTable4)) * 1e9,
    );

    println!("\n== crossbar row allocation (1024-bit rows, Figs. 3/6) ==");
    let lin = linear_row_allocation(READ_LEN, 1024);
    println!(
        "  linear buffer row: segment {} + read {} + WF band {} + temps {} = 1024 (fits: {})",
        lin.segment_bits,
        lin.read_bits,
        lin.band_bits,
        lin.temp_bits,
        lin.fits()
    );
    let aff = affine_row_allocation(READ_LEN, 1024);
    println!(
        "  affine compute row: window {} + read {} + 3 bands {} + temps {} (fits: {})",
        aff.segment_bits,
        aff.read_bits,
        aff.band_bits,
        aff.temp_bits,
        aff.fits()
    );
    println!(
        "  traceback: {} bits/instance across 7 rows + compute-row spare \
         (8-row instances, 8 concurrent)",
        traceback_bits(READ_LEN)
    );
    println!("\ncrossbar_sim OK");
}
