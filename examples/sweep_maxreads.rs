//! maxReads sweep (paper Figs. 9/10): the accuracy/throughput knob.
//!
//! Runs the full-system simulator over a measured synthetic workload for
//! maxReads in {12.5k, 25k, 50k}, projects to the paper's 389 M-read
//! dataset, and prints the paper-workload model rows next to the paper's
//! reported values. Also reports the Batched8 affine ablation.
//!
//!     cargo run --release --example sweep_maxreads [--reads N]

use dart_pim::eval::figures;
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::xbar_sim::CostSource;
use dart_pim::pim::DartPimConfig;
use dart_pim::simulator::report::{build_report, paper_workload_counts, scale_counts};
use dart_pim::simulator::{FullSystemSim, TimingMode};

fn main() {
    let n_reads: usize = std::env::args()
        .skip_while(|a| a != "--reads")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(5_000);

    println!("== measured synthetic workload ==");
    let genome = SynthConfig { len: 1_000_000, ..Default::default() }.generate();
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads = ReadSimConfig { n_reads, ..Default::default() }
        .simulate(&index.reference, |p| p as u32);

    println!(
        "{:<10} {:>12} {:>10} {:>12} {:>12} {:>10} {:>12}",
        "maxReads", "dropped", "K_L", "J_L", "J_A", "T proj(s)", "E proj(kJ)"
    );
    for max_reads in [12_500usize, 25_000, 50_000] {
        let cfg = DartPimConfig { max_reads, low_th: 0, ..Default::default() };
        let sim = FullSystemSim::new(&index, cfg.clone());
        let counts = sim.simulate(&reads);
        let scaled = scale_counts(&counts, 389_000_000, &cfg);
        let proj = build_report(&scaled, &cfg, CostSource::PaperTable4, TimingMode::PaperSerial);
        println!(
            "{:<10} {:>12} {:>10} {:>12} {:>12} {:>10.1} {:>12.1}",
            max_reads,
            counts.dropped_pairs,
            counts.k_linear,
            counts.linear_instances,
            counts.affine_instances,
            proj.exec_time_s,
            proj.energy.total() / 1e3,
        );
    }

    println!("\n== paper-workload model (Fig. 10a parity) ==");
    println!(
        "{:<10} {:>12} {:>12} {:>14} {:>12}",
        "maxReads", "T model(s)", "T paper(s)", "Batched8 T(s)", "E model(kJ)"
    );
    for (max_reads, paper_t) in [(12_500usize, 43.8), (25_000, 87.2), (50_000, 174.0)] {
        let cfg = DartPimConfig::with_max_reads(max_reads);
        let counts = paper_workload_counts(&cfg);
        let serial = build_report(&counts, &cfg, CostSource::PaperTable4, TimingMode::PaperSerial);
        let batched = build_report(&counts, &cfg, CostSource::PaperTable4, TimingMode::Batched8);
        println!(
            "{:<10} {:>12.1} {:>12.1} {:>14.1} {:>12.1}",
            max_reads,
            serial.exec_time_s,
            paper_t,
            batched.exec_time_s,
            serial.energy.total() / 1e3
        );
    }

    println!("\n{}", figures::headline());
    println!("sweep_maxreads OK");
}
