//! End-to-end validation driver (EXPERIMENTS.md §E2E): the full system on
//! a real small workload, proving all layers compose —
//!
//!   synthetic 2 Mbp reference -> donor genome (SNPs + indels) ->
//!   20k simulated 150 bp reads -> minimizer indexing -> routing/FIFO ->
//!   batched linear WF filter and affine WF + traceback executed through
//!   the AOT-compiled Pallas kernels on PJRT -> accuracy vs the
//!   exhaustive CPU oracle and the simulated origins -> full-system
//!   Eq. 6/7 report + projection to the paper's 389 M-read scale.
//!
//! `cargo run --release --example e2e_mapping` (add `--features pjrt`
//! plus `make artifacts` for the XLA engine path).
//!
//! Flags: --reads N (default 20000), --len BP (default 2000000),
//!        --engine xla|rust|bitpal (default xla), --oracle N (default 2000).

use std::time::Instant;

use dart_pim::coordinator::{Pipeline, PipelineConfig};
use dart_pim::eval::accuracy::evaluate_accuracy;
use dart_pim::eval::datavolume;
use dart_pim::genome::mutate::MutateConfig;
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::xbar_sim::CostSource;
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::{BitpalEngine, EngineKind, RustEngine};
use dart_pim::simulator::report::{build_report, scale_counts};
use dart_pim::simulator::TimingMode;

fn arg(name: &str, default: usize) -> usize {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == name)
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn arg_s(name: &str, default: &str) -> String {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == name)
        .and_then(|i| argv.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

type MapResult =
    (Vec<Option<dart_pim::coordinator::FinalMapping>>, dart_pim::coordinator::metrics::Metrics);

/// Drive the bounded streaming entry point (the production ingestion
/// path: reads flow through backpressured channels and decisions leave
/// in read order at epoch boundaries) and collect the ordered output.
fn collect_stream<E: dart_pim::runtime::WfEngine>(
    index: &MinimizerIndex,
    cfg: PipelineConfig,
    engine: E,
    reads: &[dart_pim::genome::ReadRecord],
) -> anyhow::Result<MapResult> {
    let mut mappings = Vec::with_capacity(reads.len());
    let metrics =
        Pipeline::new(index, cfg, engine).map_stream(reads.iter().cloned().map(Ok), |_, m| {
            mappings.push(m);
            Ok(())
        })?;
    Ok((mappings, metrics))
}

#[cfg(feature = "pjrt")]
fn map_with_engine(
    kind: &str,
    index: &MinimizerIndex,
    cfg: PipelineConfig,
    reads: &[dart_pim::genome::ReadRecord],
) -> anyhow::Result<MapResult> {
    if kind == "rust" {
        println!("engine: rust");
        return collect_stream(index, cfg, RustEngine, reads);
    }
    if kind == "bitpal" {
        println!("engine: bitpal (bit-parallel filter)");
        let cfg = PipelineConfig { worker_engine: EngineKind::Bitpal, ..cfg };
        return collect_stream(index, cfg, BitpalEngine::new(), reads);
    }
    let engine = dart_pim::runtime::XlaEngine::load_default()?;
    println!(
        "engine: xla/PJRT ({}), {} compiled variants",
        engine.platform(),
        engine.manifest().artifacts.len()
    );
    collect_stream(index, cfg, engine, reads)
}

#[cfg(not(feature = "pjrt"))]
fn map_with_engine(
    kind: &str,
    index: &MinimizerIndex,
    cfg: PipelineConfig,
    reads: &[dart_pim::genome::ReadRecord],
) -> anyhow::Result<MapResult> {
    if kind == "bitpal" {
        println!("engine: bitpal (bit-parallel filter)");
        let cfg = PipelineConfig { worker_engine: EngineKind::Bitpal, ..cfg };
        return collect_stream(index, cfg, BitpalEngine::new(), reads);
    }
    if kind != "rust" {
        println!("engine: rust (this build has no `pjrt` feature; --engine {kind} unavailable)");
    } else {
        println!("engine: rust");
    }
    collect_stream(index, cfg, RustEngine, reads)
}

fn main() -> anyhow::Result<()> {
    let n_reads = arg("--reads", 20_000);
    let genome_len = arg("--len", 2_000_000);
    let oracle_n = arg("--oracle", 2_000);
    let engine_kind = arg_s("--engine", "xla");

    println!("== DART-PIM end-to-end validation ==");
    let t0 = Instant::now();
    let genome = SynthConfig { len: genome_len, ..Default::default() }.generate();
    let donor = MutateConfig::default().apply(&genome);
    println!(
        "reference {} bp; donor: {} SNPs, {} indel events",
        genome_len, donor.n_snps, donor.n_indels
    );
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let stats = index.stats(DartPimConfig::default().low_th);
    println!(
        "index: {} minimizers, {} occurrences, mean {:.2}, max {}, lowTh share {:.1}% \
         | segment storage {:.1} MB vs hashtable {:.1} MB ({:.1}x, paper: 17x at human scale)",
        stats.n_minimizers,
        stats.n_occurrences,
        stats.mean_occurrences,
        stats.max_occurrences,
        100.0 * stats.low_freq_minimizers as f64 / stats.n_minimizers.max(1) as f64,
        stats.segment_storage_bytes as f64 / 1e6,
        stats.hashtable_storage_bytes as f64 / 1e6,
        stats.segment_storage_bytes as f64 / stats.hashtable_storage_bytes.max(1) as f64,
    );
    let reads = ReadSimConfig { n_reads, ..Default::default() }
        .simulate(&donor.seq, |p| donor.to_ref(p));
    println!("reads: {} x {} bp from the donor genome", reads.len(), READ_LEN);
    println!("setup {:.1?}", t0.elapsed());

    // §II motivation numbers on this workload
    let dv = datavolume::measure(&index, &reads[..reads.len().min(2000)]);
    print!("{}", datavolume::render(&dv, "data volume (sampled)"));

    // --- the mapping run ---
    // lowTh=1 at this scale (DESIGN.md §6: minimizer frequency scales
    // with genome size; the paper's lowTh=3 on 3.1 Gbp ≈ lowTh 1 here)
    let cfg = PipelineConfig {
        dart: DartPimConfig { low_th: 1, ..Default::default() },
        ..Default::default()
    };
    let t1 = Instant::now();
    let (mappings, metrics) = map_with_engine(&engine_kind, &index, cfg.clone(), &reads)?;
    println!("mapping done in {:.1?}: {}", t1.elapsed(), metrics.summary());
    println!(
        "stage times: seed {:.2?}, linear {:.2?}, affine {:.2?} (traceback {:.2?})",
        metrics.t_seed, metrics.t_linear, metrics.t_affine, metrics.t_traceback
    );

    // --- accuracy (paper §VII-A) ---
    let t2 = Instant::now();
    let sample = &reads[..reads.len().min(oracle_n)];
    let rep = evaluate_accuracy(&index, sample, &mappings[..sample.len()], 5);
    println!(
        "accuracy (n={}, oracle {:.1?}): vs BWA-MEM-analog oracle = {:.4} (exact {:.4}) \
         | vs simulated truth = {:.4}",
        sample.len(),
        t2.elapsed(),
        rep.accuracy_vs_oracle(),
        rep.oracle_exact as f64 / rep.oracle_mapped.max(1) as f64,
        rep.accuracy_vs_truth()
    );
    let mut truth_all = 0usize;
    for r in &reads {
        if let Some(m) = &mappings[r.id as usize] {
            if (m.pos - r.truth_pos as i64).abs() <= 5 {
                truth_all += 1;
            }
        }
    }
    println!(
        "all-reads truth agreement: {}/{} = {:.4} (paper: 0.997-0.998 vs BWA-MEM)",
        truth_all,
        reads.len(),
        truth_all as f64 / reads.len() as f64
    );

    // --- Eq. 6/7 hardware report from the measured workload ---
    let counts = metrics.to_sim_counts();
    let report = build_report(&counts, &cfg.dart, CostSource::PaperTable4, TimingMode::PaperSerial);
    println!(
        "\nsimulated DART-PIM on this workload: \
         T={:.4}s (dpmem {:.4} / riscv {:.4} / readout {:.4}) \
         E={:.2}J -> {:.2} Mreads/s",
        report.exec_time_s,
        report.t_dpmem_s,
        report.t_riscv_s,
        report.t_readout_s,
        report.energy.total(),
        report.throughput() / 1e6
    );
    let scaled = scale_counts(&counts, 389_000_000, &cfg.dart);
    let proj = build_report(&scaled, &cfg.dart, CostSource::PaperTable4, TimingMode::PaperSerial);
    println!(
        "projected to 389M reads (maxReads={}): \
         T={:.1}s (dpmem {:.1} / riscv {:.1} / readout {:.1}), \
         E={:.1}kJ, {:.2} Mreads/s, {:.0}W (paper @25k: 87.2s, 26.5kJ, 4.5 Mreads/s)",
        cfg.dart.max_reads,
        proj.exec_time_s,
        proj.t_dpmem_s,
        proj.t_riscv_s,
        proj.t_readout_s,
        proj.energy.total() / 1e3,
        proj.throughput() / 1e6,
        proj.avg_power_w()
    );
    if proj.t_riscv_s > proj.t_dpmem_s {
        println!(
            "  note: at this genome scale most minimizers sit below lowTh and route to the \
             RISC-V pool, which dominates the projection; the paper-workload model (see \
             sweep_maxreads / fig9 bench) uses human-scale minimizer statistics where the \
             RISC-V share is 0.16%."
        );
    }

    assert!(truth_all as f64 / reads.len() as f64 > 0.95, "e2e accuracy regression");
    assert_eq!(metrics.traceback_failures, 0, "tracebacks must never fail");
    println!("\ne2e_mapping OK ({:.1?} total)", t0.elapsed());
    Ok(())
}
