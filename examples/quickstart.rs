//! Quickstart: map a handful of simulated reads end to end through the
//! DART-PIM pipeline.
//!
//! `cargo run --release --example quickstart`
//!
//! The default (hermetic) build runs the pure-Rust WF engine. With the
//! `pjrt` feature and AOT artifacts built (`make artifacts`), the
//! same pipeline executes the compiled Pallas kernels instead — the
//! numerics are identical (tests/engine_parity.rs).

use dart_pim::coordinator::{Pipeline, PipelineConfig};
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::RustEngine;

type MapResult =
    (Vec<Option<dart_pim::coordinator::FinalMapping>>, dart_pim::coordinator::metrics::Metrics);

/// Run the pipeline on the best engine this build provides.
#[cfg(feature = "pjrt")]
fn run_mapping(
    index: &MinimizerIndex,
    cfg: PipelineConfig,
    reads: &[dart_pim::genome::ReadRecord],
) -> anyhow::Result<MapResult> {
    match dart_pim::runtime::XlaEngine::load_default() {
        Ok(engine) => {
            println!("engine: xla/PJRT ({})", engine.platform());
            Pipeline::new(index, cfg, engine).map_reads(reads)
        }
        Err(e) => {
            println!("engine: rust (artifacts unavailable: {e})");
            Pipeline::new(index, cfg, RustEngine).map_reads(reads)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn run_mapping(
    index: &MinimizerIndex,
    cfg: PipelineConfig,
    reads: &[dart_pim::genome::ReadRecord],
) -> anyhow::Result<MapResult> {
    println!("engine: rust (hermetic default build; `--features pjrt` enables XLA)");
    Pipeline::new(index, cfg, RustEngine).map_reads(reads)
}

fn main() -> anyhow::Result<()> {
    // 1. A small synthetic reference genome (stands in for GRCh38).
    let genome = SynthConfig { len: 200_000, ..Default::default() }.generate();
    println!("reference: {} bp synthetic genome", genome.len());

    // 2. Offline indexing: minimizers (k=12, W=30) -> occurrence lists.
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let stats = index.stats(3);
    println!(
        "index: {} minimizers, {} occurrences (max {})",
        stats.n_minimizers, stats.n_occurrences, stats.max_occurrences
    );

    // 3. Simulated Illumina-like reads with known origins.
    let reads = ReadSimConfig { n_reads: 200, ..Default::default() }
        .simulate(&index.reference, |p| p as u32);

    // 4. The pipeline: route -> FIFO -> linear WF filter -> affine WF +
    //    traceback -> best-so-far. lowTh=0 keeps all work on the
    //    "crossbar" path at this small scale (see DESIGN.md §6).
    let cfg = PipelineConfig {
        dart: DartPimConfig { low_th: 0, ..Default::default() },
        ..Default::default()
    };
    let (mappings, metrics) = run_mapping(&index, cfg, &reads)?;
    println!("metrics: {}", metrics.summary());

    // 5. Check against the simulated origins.
    let mut correct = 0;
    for r in &reads {
        if let Some(m) = &mappings[r.id as usize] {
            if (m.pos - r.truth_pos as i64).abs() <= 5 {
                correct += 1;
            }
        }
    }
    println!("mapped {}/{} reads within ±5 bp of their origin", correct, reads.len());
    for (i, m) in mappings.iter().flatten().take(5).enumerate() {
        println!(
            "  example {}: read {} -> pos {} dist {} cigar {}",
            i, m.read_id, m.pos, m.dist, m.cigar
        );
    }
    assert!(correct as f64 / reads.len() as f64 > 0.9, "quickstart accuracy regression");
    println!("quickstart OK");
    Ok(())
}
